"""ApproxEigenbasis: one facade over both factorization families, batched.

The paper factors an eigenspace into g fundamental components — extended
orthogonal Givens transforms for symmetric matrices (Algorithm 1 with
Theorems 1-2 + Lemma 1) or scaling/shear transforms for general matrices
(Theorems 3-4 + Lemma 2).  The seed exposed those as two parallel APIs
(core/gtransform.py, core/ttransform.py) that factor ONE matrix at a time.
This module is the single entry point and the batched engine (DESIGN.md §7):

  * ``fit`` runs Algorithm 1 for a whole stack of B matrices inside one
    jitted program — the solver cores are pure ``lax`` control flow, so
    ``jit(vmap(core))`` runs B greedy factorizations in lockstep, and a
    device mesh shards the matrix batch across the data axes
    (runtime/sharding.py + launch/mesh.py).
  * ``apply`` / ``project`` route through the batched staged tables
    ((B, S, P); core/staging.py) into the fused Pallas kernels
    (kernels/butterfly.py, kernels/shear.py) with the vmapped ref.py
    oracle as the ``backend="xla"`` fallback.
  * ``save`` / ``load`` persist factors + spectrum through the
    fault-tolerant checkpoint store (checkpoint/store.py; DESIGN.md §6).

Everything also works unbatched ((n, n) input) so single-matrix callers can
migrate from the two legacy APIs without behavior change.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import gtransform as gt
from . import ttransform as tt
from .staging import (StagedG, StagedT, default_cut_ladder,
                      pack_g_batch_pair, pack_g_pair, pack_t_batch_pair,
                      pack_t_pair, select_cut)
from .types import GFactors, TFactors

SYMMETRIC = "sym"
GENERAL = "general"


# ---------------------------------------------------------------------------
# Cached jitted fit programs (one compile per (kind, g, hyperparam) combo)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sym_fit_program(g: int, n_iter: int, update_spectrum: bool,
                     eps: float, score: str, batched: bool,
                     masked: bool = False):
    if masked:
        def one(s_mat, sbar0, size):
            return gt._approx_sym_core(
                s_mat, sbar0, g, n_iter, update_spectrum,
                jnp.asarray(eps, s_mat.dtype), score, size)
    else:
        def one(s_mat, sbar0):
            return gt._approx_sym_core(
                s_mat, sbar0, g, n_iter, update_spectrum,
                jnp.asarray(eps, s_mat.dtype), score)

    return jax.jit(jax.vmap(one) if batched else one)


@functools.lru_cache(maxsize=None)
def _gen_fit_program(m: int, n_iter: int, update_spectrum: bool,
                     eps: float, batched: bool, masked: bool = False):
    if masked:
        def one(c_mat, cbar0, size):
            return tt._approx_gen_core(
                c_mat, cbar0, m, n_iter, update_spectrum,
                jnp.asarray(eps, c_mat.dtype), size)
    else:
        def one(c_mat, cbar0):
            return tt._approx_gen_core(
                c_mat, cbar0, m, n_iter, update_spectrum,
                jnp.asarray(eps, c_mat.dtype))

    return jax.jit(jax.vmap(one) if batched else one)


@functools.lru_cache(maxsize=None)
def _sym_extend_program(g_extra: int, n_iter: int, update_spectrum: bool,
                        eps: float, score: str, batched: bool,
                        masked: bool = False):
    """Warm-start extension program, cached like the fit programs: one
    compile per (g_extra, hyperparam) combo serves every batch."""
    if masked:
        def one(s_mat, fi, fj, fc, fs, fsg, sbar, size):
            return gt._extend_sym_core(
                s_mat, GFactors(fi, fj, fc, fs, fsg), sbar, g_extra,
                n_iter, update_spectrum, jnp.asarray(eps, s_mat.dtype),
                score, size)
    else:
        def one(s_mat, fi, fj, fc, fs, fsg, sbar):
            return gt._extend_sym_core(
                s_mat, GFactors(fi, fj, fc, fs, fsg), sbar, g_extra,
                n_iter, update_spectrum, jnp.asarray(eps, s_mat.dtype),
                score)

    return jax.jit(jax.vmap(one) if batched else one)


@functools.lru_cache(maxsize=None)
def _gen_extend_program(m_extra: int, n_iter: int, update_spectrum: bool,
                        eps: float, batched: bool, masked: bool = False):
    if masked:
        def one(c_mat, fk, fi, fj, fa, cbar, size):
            return tt._extend_gen_core(
                c_mat, TFactors(fk, fi, fj, fa), cbar, m_extra, n_iter,
                update_spectrum, jnp.asarray(eps, c_mat.dtype), size)
    else:
        def one(c_mat, fk, fi, fj, fa, cbar):
            return tt._extend_gen_core(
                c_mat, TFactors(fk, fi, fj, fa), cbar, m_extra, n_iter,
                update_spectrum, jnp.asarray(eps, c_mat.dtype))

    return jax.jit(jax.vmap(one) if batched else one)


def _is_symmetric(mats: jnp.ndarray) -> bool:
    # on-device reduction: only one scalar crosses to the host (the batch
    # may be large and already device-resident)
    return bool(jnp.allclose(mats, jnp.swapaxes(mats, -1, -2), atol=1e-6))


def pad_ragged(mats, width: Optional[int] = None
               ) -> tuple[jnp.ndarray, np.ndarray]:
    """Zero-pad a heterogeneous fleet of square matrices into one bucket.

    ``mats``: a sequence of (n_b, n_b) arrays (sizes may differ).  Returns
    ``(stack, sizes)`` with ``stack`` a (B, n, n) f32 stack (``n`` =
    ``width`` or the largest size) and ``sizes`` the (B,) true sides.
    The zero pad block is exactly representable: a masked fit
    (``ApproxEigenbasis.fit(..., sizes=sizes)``) acts as the identity on
    coordinates >= n_b, so each matrix factors as its own-size fit would
    (DESIGN.md §10)."""
    arrs = [np.asarray(m, np.float32) for m in mats]
    for a in arrs:
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"ragged fleet entries must be square "
                             f"matrices, got shape {a.shape}")
    if not arrs:
        raise ValueError("empty ragged fleet")
    sizes = np.asarray([a.shape[0] for a in arrs], np.int64)
    n = int(width) if width is not None else int(sizes.max())
    if n < int(sizes.max()):
        raise ValueError(f"bucket width {n} < largest matrix "
                         f"{int(sizes.max())}")
    out = np.zeros((len(arrs), n, n), np.float32)
    for b, a in enumerate(arrs):
        out[b, :a.shape[0], :a.shape[0]] = a
    return jnp.asarray(out), sizes


def _zero_pad_block(mats: jnp.ndarray, sizes) -> jnp.ndarray:
    """Enforce the ragged-embedding precondition: coordinates >= the true
    size are zeroed.  The masked greedy never SELECTS a pad pair either
    way, but the polish/Lemma value refits and the reported objective
    integrate whole rows/cols — a caller-padded stack with garbage in the
    pad block would silently corrupt them, so the contract is enforced
    rather than assumed."""
    if sizes is None:
        return mats
    n = mats.shape[-1]
    valid = jnp.arange(n) < jnp.asarray(np.asarray(sizes))[..., None]
    return jnp.where(
        jnp.logical_and(valid[..., :, None], valid[..., None, :]),
        mats, 0.0)


def _normalize_sizes(sizes, batched: bool, n: int, batch: int):
    """Validate/canonicalize a ``sizes`` argument.

    Returns host metadata: an (B,) int64 array for a batched fit, an int
    for an unbatched one — or None when every matrix fills the bucket
    (the unmasked programs are strictly cheaper)."""
    if sizes is None:
        return None
    sizes = np.asarray(sizes)
    if batched:
        if sizes.shape != (batch,):
            raise ValueError(f"sizes must be ({batch},) to match the "
                             f"matrix batch, got {sizes.shape}")
        sizes = sizes.astype(np.int64)
    else:
        if sizes.ndim != 0:
            raise ValueError(f"unbatched fit takes a scalar size, got "
                             f"shape {sizes.shape}")
        sizes = np.int64(sizes)
    if np.any(sizes < 2) or np.any(sizes > n):
        raise ValueError(f"sizes must lie in [2, {n}], got {sizes}")
    if np.all(sizes == n):
        return None
    return int(sizes) if not batched else sizes


@dataclass
class ApproxEigenbasis:
    """A fitted fast approximate eigenbasis (single matrix or a batch).

    Attributes:
      kind: "sym" (G-transforms) or "general" (T-transforms).
      n: matrix side.
      batched: True when ``factors``/``spectrum`` carry a leading batch dim.
      factors: GFactors (g,)-arrays or TFactors (m,)-arrays; (B, g)/(B, m)
        when batched.
      spectrum: estimated eigenvalues, (n,) or (B, n) f32.
      fwd: staged Ubar / Tbar tables, (S, P) or (B, S, P).
      bwd: staged Ubar^T / Tbar^{-1} tables, same layout.
      objective: final ||M - reconstruction||_F^2, scalar or (B,).
      info: fit diagnostics (objective history, iteration counts).
      sizes: true matrix sides for a ragged (masked) fit — (B,) int64 host
        array / int, or None when every matrix fills the bucket.  A masked
        basis acts as the identity on coordinates >= sizes[b] (DESIGN.md
        §10): ``apply`` passes those signal coordinates through untouched
        and ``project`` zeroes them (the padded spectrum is zero).
    """

    kind: str
    n: int
    batched: bool
    factors: Union[GFactors, TFactors]
    spectrum: jnp.ndarray
    fwd: Union[StagedG, StagedT]
    bwd: Union[StagedG, StagedT]
    objective: Optional[jnp.ndarray] = None
    info: Dict[str, Any] = field(default_factory=dict)
    sizes: Optional[Any] = None

    # -- fitting -----------------------------------------------------------

    @classmethod
    def fit(cls, mats, num_transforms: int, *,
            kind: str = "auto", hint: Optional[str] = None,
            n_iter: int = 8, eps: float = 1e-3,
            update_spectrum: bool = True,
            spectrum: Optional[jnp.ndarray] = None,
            score: Optional[str] = None,
            sizes=None,
            mesh: Optional[Any] = None,
            stage_pad: Optional[tuple] = None) -> "ApproxEigenbasis":
        """Factor one matrix (n, n) or a batch (B, n, n) — Algorithm 1.

        A batch runs inside ONE jit: the B greedy factorizations advance in
        lockstep (vmapped Theorem-1/3 init + Theorem-2/4 polish sweeps +
        Lemma-1/2 spectrum refits), which is the embarrassing per-matrix
        parallelism of the problem.  With ``mesh`` the batch is device_put
        against the mesh's data axes first, so the same program runs SPMD
        across devices (DESIGN.md §7).

        Heterogeneous fleets (DESIGN.md §10): ``mats`` may be a LIST of
        square matrices with different sides — they are zero-padded into
        one (B, n, n) bucket (``pad_ragged``) and fitted with the greedy
        masked to each matrix's true coordinates, so every factor chain
        acts as the identity on its padding block and the per-matrix
        result matches the matrix's own-size fit.  Alternatively pass an
        already-padded stack plus ``sizes`` ((B,) true sides; the pad
        block must be zero).

        ``kind="auto"`` picks "sym" when the input is (numerically)
        symmetric; pass ``kind="sym"``/``"general"`` to force a family, or
        ``hint`` to keep auto-detection but get a warning when it resolves
        against the caller's expectation (e.g. a directed graph whose
        Laplacian happens to be numerically symmetric would silently route
        through the G path).  ``score``/``spectrum`` have the same meaning
        as in ``approximate_symmetric``; ``score`` applies to the
        symmetric family only and is rejected (not silently dropped) for
        a general-family fit.

        ``stage_pad``: optional (depth_quantum, width_quantum) staged-
        table shape quantization for BATCHED fits (core/staging.py;
        DESIGN.md §11): rounding each chunk's depth / the stage width up
        to fixed quanta makes repeated refits of the same (B, n, g)
        problem land on identical table shapes, so every jitted program
        holding the tables as arguments (drift scoring, serving tiers)
        hits its compile cache instead of retracing.  The dynamic serve
        engines fit with ``stage_pad=(4, 8)``.
        """
        if isinstance(mats, (list, tuple)):
            if sizes is not None:
                raise ValueError("pass sizes= only with a pre-padded "
                                 "stack; a ragged list derives its own")
            mats, sizes = pad_ragged(mats)
        mats = jnp.asarray(mats, jnp.float32)
        if mats.ndim not in (2, 3):
            raise ValueError(f"expected (n, n) or (B, n, n), got {mats.shape}")
        batched = mats.ndim == 3
        n = mats.shape[-1]
        if mats.shape[-2] != n:
            raise ValueError(f"matrices must be square, got {mats.shape}")
        sizes = _normalize_sizes(sizes, batched, n,
                                 mats.shape[0] if batched else 0)
        mats = _zero_pad_block(mats, sizes)
        if hint not in (None, SYMMETRIC, GENERAL):
            raise ValueError(f"unknown hint {hint!r}; expected "
                             f"{SYMMETRIC!r} or {GENERAL!r}")
        if kind == "auto":
            kind = SYMMETRIC if _is_symmetric(mats) else GENERAL
            if hint is not None and hint != kind:
                warnings.warn(
                    f"kind='auto' resolved to {kind!r}, overriding the "
                    f"caller hint {hint!r}; pass kind={hint!r} to force "
                    "that factorization family", stacklevel=2)
        if kind == GENERAL and score is not None:
            raise ValueError(
                f"score={score!r} applies to the symmetric (G-transform) "
                "family only; the general (T-transform) greedy has no "
                "score variant — drop the argument or force kind='sym'")
        if spectrum is not None:
            spectrum = jnp.asarray(spectrum, jnp.float32)
            want = mats.shape[:-2] + (n,)
            if spectrum.shape != want:
                raise ValueError(
                    f"spectrum shape {spectrum.shape} does not match the "
                    f"fitted batch: expected {want}")
        if mesh is not None and batched:
            # unbatched (n, n) input has no batch axis to spread — only a
            # (B, n, n) stack shards; awkward B falls back to replication
            from repro.runtime.sharding import matrix_batch_sharding
            mats = jax.device_put(
                mats, matrix_batch_sharding(mesh, mats.ndim,
                                            batch=mats.shape[0]))
        masked = sizes is not None
        size_arg = (jnp.asarray(sizes, jnp.int32),) if masked else ()

        if kind == SYMMETRIC:
            if score is None:
                score = "paper" if spectrum is not None else "gamma"
            sbar0 = (spectrum if spectrum is not None
                     else gt.default_sbar(mats, sizes))
            fit_fn = _sym_fit_program(num_transforms, n_iter,
                                      update_spectrum, float(eps), score,
                                      batched, masked)
            factors, sbar, obj, hist, iters = fit_fn(mats, sbar0, *size_arg)
            fwd, bwd = (pack_g_batch_pair(factors, n, pad=stage_pad)
                        if batched else pack_g_pair(factors, n=n))
            return cls(kind=SYMMETRIC, n=n, batched=batched,
                       factors=factors, spectrum=sbar, fwd=fwd, bwd=bwd,
                       objective=obj,
                       info={"history": hist, "iterations": iters,
                             "score": score, "stage_pad": stage_pad},
                       sizes=sizes)

        if kind == GENERAL:
            cbar0 = (spectrum if spectrum is not None
                     else tt.default_cbar(mats, sizes))
            fit_fn = _gen_fit_program(num_transforms, n_iter,
                                      update_spectrum, float(eps), batched,
                                      masked)
            factors, cbar, obj, hist, iters = fit_fn(mats, cbar0, *size_arg)
            fwd, bwd = (pack_t_batch_pair(factors, n, pad=stage_pad)
                        if batched else pack_t_pair(factors, n))
            return cls(kind=GENERAL, n=n, batched=batched,
                       factors=factors, spectrum=cbar, fwd=fwd, bwd=bwd,
                       objective=obj,
                       info={"history": hist, "iterations": iters,
                             "stage_pad": stage_pad},
                       sizes=sizes)

        raise ValueError(f"unknown kind {kind!r}")

    # -- warm-start extension (DESIGN.md §9) -------------------------------

    @property
    def num_transforms(self) -> int:
        """Number of fitted fundamental components g (per matrix)."""
        return int(np.asarray(self.factors[0]).shape[-1])

    @property
    def stage_cuts(self) -> np.ndarray:
        """(C, 2) array of exact (num_stages, num_components) anytime
        boundaries of the staged tables (core/staging.py)."""
        return self.fwd.cuts

    def select_tier(self, fraction: Optional[float] = None,
                    num_transforms: Optional[int] = None) -> tuple:
        """Pick the exact stage cut nearest a component target; returns
        ``(num_stages, num_components)`` for ``apply``/``project``."""
        return select_cut(self.fwd, num_transforms=num_transforms,
                          fraction=fraction)

    def extend(self, mats: jnp.ndarray, num_transforms: int, *,
               n_iter: int = 0, eps: float = 1e-3,
               update_spectrum: bool = True, score: Optional[str] = None,
               mesh: Optional[Any] = None,
               stage_pad: Optional[tuple] = None) -> "ApproxEigenbasis":
        """Grow this fit to ``num_transforms`` total components WITHOUT
        refitting the prefix: Theorem-1/3-initialized components are
        greedily appended against the current residual (the greedy
        continues exactly where a from-scratch init would stand after the
        first g components), so the extended basis's anytime prefix of the
        ORIGINAL g components is the original basis.  ``n_iter`` > 0
        additionally re-sweeps the whole chain (fitted prefix included)
        with the usual polish/Lemma refinement.

        ``mats``: the same (n, n) / (B, n, n) stack this basis was fitted
        to (the basis stores factors, not matrices; a ragged fit extends
        against the same zero-padded bucket stack and keeps its masking).
        Batched fits extend under one jit(vmap) program, cached like the
        fit programs.  The extended tables' cut ladder includes the
        ORIGINAL g, so the pre-extension basis remains selectable as a
        serving tier.  ``score`` defaults to the score the fit resolved
        (recorded in ``info`` and restored by ``load``); like ``fit`` it
        is rejected for the general family."""
        mats = jnp.asarray(mats, jnp.float32)
        if mats.ndim != (3 if self.batched else 2):
            raise ValueError(f"expected {'batched ' if self.batched else ''}"
                             f"matrices matching the fit, got {mats.shape}")
        if mats.shape[-1] != self.n or mats.shape[-2] != self.n:
            raise ValueError(f"matrix side {mats.shape[-1]} != fitted "
                             f"n={self.n}")
        if self.kind != SYMMETRIC and score is not None:
            raise ValueError(
                f"score={score!r} applies to the symmetric (G-transform) "
                "family only; this basis is kind='general'")
        g_old = self.num_transforms
        extra = num_transforms - g_old
        if extra <= 0:
            raise ValueError(f"num_transforms must exceed the fitted "
                             f"{g_old}, got {num_transforms}")
        n = self.n
        if mesh is not None and self.batched:
            from repro.runtime.sharding import matrix_batch_sharding
            mats = jax.device_put(
                mats, matrix_batch_sharding(mesh, mats.ndim,
                                            batch=mats.shape[0]))
        masked = self.sizes is not None
        mats = _zero_pad_block(mats, self.sizes)
        size_arg = (jnp.asarray(self.sizes, jnp.int32),) if masked else ()
        # keep the pre-extension basis selectable as a tier: the new
        # ladder carries the original g as an extra exact cut
        cuts = sorted(set(default_cut_ladder(num_transforms).tolist())
                      | {g_old})
        if stage_pad is None:     # keep the fit's shape quantization
            stage_pad = self.info.get("stage_pad")
        info = {"extended_from": g_old, "stage_pad": stage_pad}
        if self.kind == SYMMETRIC:
            if score is None:
                score = self.info.get("score", "gamma")
            info["score"] = score  # chained extends keep the criterion
            fit_fn = _sym_extend_program(extra, n_iter, update_spectrum,
                                         float(eps), score, self.batched,
                                         masked)
            factors, sbar, obj, hist, iters = fit_fn(
                mats, *self.factors, self.spectrum, *size_arg)
            fwd, bwd = (pack_g_batch_pair(factors, n, cuts=cuts,
                                          pad=stage_pad)
                        if self.batched
                        else pack_g_pair(factors, cuts=cuts, n=n))
        else:
            fit_fn = _gen_extend_program(extra, n_iter, update_spectrum,
                                         float(eps), self.batched, masked)
            factors, sbar, obj, hist, iters = fit_fn(
                mats, *self.factors, self.spectrum, *size_arg)
            fwd, bwd = (pack_t_batch_pair(factors, n, cuts=cuts,
                                          pad=stage_pad)
                        if self.batched
                        else pack_t_pair(factors, n, cuts=cuts))
        info.update(history=hist, iterations=iters)
        return type(self)(kind=self.kind, n=n, batched=self.batched,
                          factors=factors, spectrum=sbar, fwd=fwd, bwd=bwd,
                          objective=obj, info=info, sizes=self.sizes)

    # -- application (plan-backed: one cached program per shape; ----------
    # -- DESIGN.md §13) ----------------------------------------------------

    def _plan(self, mode: str, backend: str, num_stages: Optional[int],
              precision: str, keep: str = "head", fused: bool = True):
        from repro.kernels.plan import ApplyPlan
        return ApplyPlan(family=self.kind, mode=mode, n=self.n,
                         batched=self.batched, backend=backend,
                         num_stages=num_stages, keep=keep,
                         precision=precision, fused=fused)

    def apply(self, x: jnp.ndarray, inverse: bool = False,
              backend: str = "xla", num_stages: Optional[int] = None,
              precision: str = "f32") -> jnp.ndarray:
        """y = Ubar x (or Tbar x); ``inverse=True`` applies Ubar^T /
        Tbar^{-1} (graph Fourier ANALYSIS; forward is SYNTHESIS).

        ``x``: (..., n), with a leading (B, ...) batch when ``batched``.
        ``num_stages`` runs the anytime prefix (pick a boundary with
        ``select_tier``; DESIGN.md §9).  ``precision="bf16"`` runs bf16
        table storage with f32 accumulation (DESIGN.md §13).
        """
        from repro.kernels.plan import leg_orientation
        staged = self.bwd if inverse else self.fwd
        keep = leg_orientation(self.kind)[0 if inverse else 1]
        plan = self._plan("apply", backend, num_stages, precision, keep)
        return plan.apply(staged, x)

    def project(self, x: jnp.ndarray,
                h: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
                backend: str = "xla",
                num_stages: Optional[int] = None,
                precision: str = "f32", fused: bool = True) -> jnp.ndarray:
        """Apply the reconstructed operator (a spectral projection/filter):

            y = Ubar diag(h(spectrum)) Ubar^T x      (symmetric)
            y = Tbar diag(h(spectrum)) Tbar^{-1} x   (general)

        ``h`` defaults to the identity (the approximated matrix itself).
        ``backend="pallas"`` runs the fused one-round-trip kernel; batched
        instances use the (B, S, P)-table batched kernels (DESIGN.md §4,
        §7).  ``num_stages`` truncates both transform legs to the same
        anytime component prefix (DESIGN.md §9).  On a ragged basis the
        gains are zeroed at each matrix's padding coordinates — the padded
        spectrum slots are 0 but ``h(0)`` need not be (heat/Tikhonov map
        0 -> 1), and the transforms pass pad coordinates through, so an
        unmasked ``h`` would leak pad columns of ``x`` into the output.
        ``precision="bf16"``/``fused=False`` select the plan layer's
        storage-precision and three-pass baseline paths (DESIGN.md
        §13)."""
        d = self.spectrum if h is None else h(self.spectrum)
        if h is not None and self.sizes is not None:
            valid = (np.arange(self.n)
                     < np.asarray(self.sizes)[..., None])
            d = jnp.where(jnp.asarray(valid), d, 0.0)
        plan = self._plan("operator", backend, num_stages, precision,
                          fused=fused)
        return plan.operator(self.fwd, self.bwd, d, x)

    def to_dense(self, num_stages: Optional[int] = None) -> jnp.ndarray:
        """Materialize the basis: Ubar / Tbar as (n, n) or (B, n, n)
        (``num_stages``: the anytime prefix basis instead of the full
        one)."""
        eye = jnp.eye(self.n, dtype=jnp.float32)
        if self.batched:
            b = self.spectrum.shape[0]
            eye = jnp.broadcast_to(eye, (b, self.n, self.n))
        # staged apply acts on row vectors: row r of the result is
        # (basis e_r), i.e. the transpose of the basis matrix
        return jnp.swapaxes(self.apply(eye, num_stages=num_stages),
                            -1, -2)

    def reconstruct(self) -> jnp.ndarray:
        """Dense approximation  Ubar diag(s) Ubar^T  /  Tbar diag(c)
        Tbar^{-1}  as (n, n) or (B, n, n) (small-n evaluation only)."""
        eye = jnp.eye(self.n, dtype=jnp.float32)
        if self.batched:
            b = self.spectrum.shape[0]
            eye = jnp.broadcast_to(eye, (b, self.n, self.n))
        return jnp.swapaxes(self.project(eye), -1, -2)

    def frobenius_error(self, mats: jnp.ndarray) -> jnp.ndarray:
        """||M - reconstruction||_F^2 per matrix (scalar or (B,))."""
        diff = jnp.asarray(mats, jnp.float32) - self.reconstruct()
        return jnp.sum(diff * diff, axis=(-2, -1))

    def shard(self, mesh) -> "ApproxEigenbasis":
        """Device_put the staged tables + spectrum against ``mesh``: the
        leading matrix-batch axis maps to the mesh's data axes, so
        ``apply``/``project`` on (B, ..., n) signals run SPMD without any
        code change (runtime/sharding.py)."""
        if not self.batched:
            return self
        from repro.runtime.sharding import matrix_batch_sharding
        batch = int(self.spectrum.shape[0])

        def put(leaf):
            if isinstance(leaf, (int, np.integer)) or leaf is None:
                return leaf
            if isinstance(leaf, np.ndarray):
                return leaf  # host metadata (the cuts ladder) stays host
            return jax.device_put(
                leaf, matrix_batch_sharding(mesh, jnp.ndim(leaf),
                                            batch=batch))

        fwd = type(self.fwd)(*(put(l) for l in self.fwd))
        bwd = type(self.bwd)(*(put(l) for l in self.bwd))
        return replace(self, fwd=fwd, bwd=bwd, spectrum=put(self.spectrum))

    # -- persistence (checkpoint/store.py; DESIGN.md §6) --------------------

    def save(self, directory, step: int = 0, *,
             extra_state: Optional[Dict[str, Any]] = None,
             extra_metadata: Optional[Dict[str, Any]] = None,
             shards: int = 1):
        """Persist factors + spectrum via the atomic checkpoint store.

        ``extra_state``: additional leaves saved alongside (``load``
        ignores them; callers restore them with their own ``state_like``
        — the dynamic serve engines persist their tracked Laplacians this
        way).  ``extra_metadata``: JSON-able keys merged into the
        manifest metadata next to the ``eigenbasis`` block.  ``shards``:
        per-shard table files (mesh-placed engines pass their device
        count; ``load`` reassembles on any mesh — DESIGN.md §14)."""
        from repro.checkpoint import save_checkpoint
        state = {"factors": self.factors, "spectrum": self.spectrum}
        for key, leaf in (extra_state or {}).items():
            if key in state:
                raise ValueError(f"extra_state key {key!r} collides with "
                                 "the basis state")
            state[key] = leaf
        meta = dict(extra_metadata or {})
        if "eigenbasis" in meta:
            raise ValueError("extra_metadata must not carry an "
                             "'eigenbasis' key")
        meta.update({
            "eigenbasis": {
                "kind": self.kind, "n": self.n, "batched": self.batched,
                "num_transforms": int(
                    np.asarray(self.factors[0]).shape[-1]),
                "batch": (int(self.spectrum.shape[0]) if self.batched
                          else 0),
                # anytime prefix metadata (DESIGN.md §9): load() repacks
                # the staged tables deterministically, so recording the
                # ladder here both documents the serving tiers a restored
                # basis offers and lets load() verify the repack
                "num_stages": int(self.fwd.num_stages),
                "stage_cuts": (np.asarray(self.fwd.cuts).tolist()
                               if self.fwd.cuts is not None else None),
                # the fit's resolved greedy criterion and final objective:
                # without these a restored basis would EXTEND under the
                # default "gamma" score even when the fit used "paper",
                # silently switching the greedy mid-chain
                "score": self.info.get("score"),
                "objective": (np.asarray(self.objective,
                                         np.float64).tolist()
                              if self.objective is not None else None),
                # ragged-fleet masking (DESIGN.md §10)
                "sizes": (np.asarray(self.sizes).tolist()
                          if self.sizes is not None else None),
                # dynamic-subsystem basis version (DESIGN.md §11): bumped
                # by the serving layer on every hot swap; pre-versioned
                # checkpoints simply lack the key and load() defaults it
                # to 0
                "version": int(self.info.get("version", 0)),
                # staged-table shape quantization (DESIGN.md §11): load()
                # must repack with the same quanta or the cut ladder's
                # stage indices would shift
                "stage_pad": (list(self.info["stage_pad"])
                              if self.info.get("stage_pad") else None),
            }
        })
        return save_checkpoint(directory, step, state, metadata=meta,
                               shards=shards)

    @classmethod
    def load(cls, directory, step: Optional[int] = None
             ) -> "ApproxEigenbasis":
        """Restore a fitted basis and rebuild its staged tables."""
        from repro.checkpoint import (read_metadata, restore_checkpoint,
                                      latest_step)
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no committed checkpoint in {directory}")
        meta = read_metadata(directory, step).get("eigenbasis")
        if meta is None:
            raise ValueError(f"checkpoint at {directory} does not hold an "
                             "ApproxEigenbasis state")
        kind, n = meta["kind"], int(meta["n"])
        batched = bool(meta["batched"])
        g = int(meta["num_transforms"])
        shape = (int(meta["batch"]), g) if batched else (g,)
        nsh = (int(meta["batch"]), n) if batched else (n,)
        zi = jnp.zeros(shape, jnp.int32)
        zf = jnp.zeros(shape, jnp.float32)
        if kind == SYMMETRIC:
            factors_like = GFactors(i=zi, j=zi, c=zf, s=zf, sigma=zf)
        else:
            factors_like = TFactors(kind=zi, i=zi, j=zi, a=zf)
        like = {"factors": factors_like,
                "spectrum": jnp.zeros(nsh, jnp.float32)}
        state, _, _ = restore_checkpoint(directory, like, step=step)
        factors, spectrum = state["factors"], state["spectrum"]
        stage_pad = meta.get("stage_pad")
        if stage_pad is not None:
            stage_pad = tuple(int(q) for q in stage_pad)
        # repack with the checkpoint's COMPONENT ladder: an extended
        # basis carries its pre-extension g as an extra exact cut, which
        # the default quarters ladder would silently drop
        cuts = None
        if meta.get("stage_cuts") is not None:
            cuts = sorted({int(row[1]) for row in meta["stage_cuts"]})
        if kind == SYMMETRIC:
            fwd, bwd = (pack_g_batch_pair(factors, n, cuts=cuts,
                                          pad=stage_pad)
                        if batched else pack_g_pair(factors, cuts=cuts,
                                                    n=n))
        else:
            fwd, bwd = (pack_t_batch_pair(factors, n, cuts=cuts,
                                          pad=stage_pad)
                        if batched else pack_t_pair(factors, n,
                                                    cuts=cuts))
        saved_cuts = meta.get("stage_cuts")
        if (saved_cuts is not None and fwd.cuts is not None
                and np.asarray(fwd.cuts).tolist() != saved_cuts):
            warnings.warn(
                "restored staged tables repacked with a different anytime "
                "cut ladder than the checkpoint recorded (packing defaults "
                "changed?); serving tiers pinned to the old ladder's stage "
                "counts must be re-selected via select_tier", stacklevel=2)
        # restore the fit's resolved scoring criterion + objective so a
        # post-restore extend() keeps the original greedy criterion
        # (pre-fix checkpoints carry neither key -> .get defaults)
        info: Dict[str, Any] = {}
        if meta.get("score") is not None:
            info["score"] = meta["score"]
        # dynamic-subsystem version: pre-versioned checkpoints carry no
        # key and restore as version 0 (DESIGN.md §11)
        info["version"] = int(meta.get("version", 0))
        info["stage_pad"] = stage_pad
        objective = None
        if meta.get("objective") is not None:
            objective = jnp.asarray(meta["objective"], jnp.float32)
        sizes = meta.get("sizes")
        if sizes is not None:
            sizes = (np.asarray(sizes, np.int64) if batched
                     else int(sizes))
        return cls(kind=kind, n=n, batched=batched, factors=factors,
                   spectrum=spectrum, fwd=fwd, bwd=bwd,
                   objective=objective, info=info, sizes=sizes)
