"""Roofline-term derivation from a compiled XLA executable.

The container is CPU-only, so all performance numbers are *derived from the
compiled artifact*, never measured:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bandwidth_per_chip
  collective term = collective_bytes_per_device / ICI_link_bandwidth

``compiled.cost_analysis()`` reports flops / bytes of the *partitioned*
per-device module, so the terms above are already per-chip (equivalent to
the assignment's global-quantity / (chips x per-chip-rate) form).

collective_bytes is not in cost_analysis: we parse the optimized HLO text
(``compiled.as_text()``) and sum the result-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op.  For all-reduce we count 2x result bytes (reduce + broadcast phases of
a ring); for reduce-scatter the result is the shard, which is what each
chip receives; for all-gather the result is the gathered tensor, an upper
bound on per-chip traffic.  The breakdown per op kind is also returned so
the perf loop can see *which* collective dominates.
"""
from __future__ import annotations

import re
from typing import Any, Dict

import numpy as np

# TPU v5e hardware constants (assignment-provided)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip usable)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "f32[256,1024]{1,0}" (layout suffix optional, scalars "f32[]")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all tensor shapes in a (possibly tuple) HLO type."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes of collective ops in optimized HLO text."""
    by_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op definitions look like:  %name = TYPE kind(...)  (fusions never
        # contain collectives, so a flat line scan is exact)
        if "= " not in stripped:
            continue
        lhs, rhs = stripped.split("= ", 1)
        for kind in _COLLECTIVES:
            # Sync form: "TYPE kind(...)".  Async pairs lower as
            # "kind-start" + "kind-done"; we count the -done, whose result
            # type is the final buffer (the -start result is a state tuple).
            m = re.match(rf"(.+?)\s{kind}(-done)?\(", rhs)
            if m and f"{kind}-start(" not in rhs:
                b = _shape_bytes(m.group(1))
                mult = 2 if kind == "all-reduce" else 1
                by_kind[kind] += mult * b
                counts[kind] += 1
                break
    total = sum(by_kind.values())
    return {"total_bytes": total, "by_kind": by_kind, "counts": counts}


def cost_summary(compiled) -> Dict[str, float]:
    """flops / bytes from compiled.cost_analysis() (per-device module)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0) or 0)
    # peak live bytes (aliased args+outputs counted once)
    out["per_device_bytes"] = (out["argument_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               - out["alias_size_in_bytes"])
    return out


# ---------------------------------------------------------------------------
# Loop-aware HLO walking.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE, ignoring the trip
# count (verified empirically on this jaxlib: a fori_loop of k matmuls
# reports the flops of one).  Every layer stack here is a lax.scan, so the
# naive numbers undercount by ~n_layers x n_chunks.  This walker parses the
# optimized HLO text, resolves each while loop's trip count from its
# condition's comparison constant, and accumulates:
#   * dot/convolution FLOPs (the MXU term; elementwise flops are noise at
#     these shapes),
#   * HBM bytes as operand+result bytes of each top-level op per execution
#     (fusion internals excluded — they stay in registers/VMEM, so fusion
#     parameters/results model materialized traffic),
#   * collective bytes by kind,
# each multiplied by the product of enclosing trip counts.
# ---------------------------------------------------------------------------

_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_BRANCH_RE = re.compile(
    r"(?:true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+)|"
    r"branch_computations={([^}]*)})")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """computation name -> list of body lines."""
    comps: Dict[str, list] = {}
    cur = None
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
    for line in hlo_text.splitlines():
        if cur is None:
            if not line or line[0] in " }":
                continue
            m = header.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _parse_op(line: str):
    """'%name = TYPE kind(args), attrs' -> (name, type_str, kind, rest).

    Handles tuple types (parenthesized) on the RHS."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not s.startswith("%"):
        return None
    name = s[1:eq]
    rhs = s[eq + 3:]
    if rhs.startswith("("):           # tuple type: find matching paren
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        rtype = rhs[:i + 1]
        rest = rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        rtype = rhs[:sp]
        rest = rhs[sp + 1:].lstrip()
    par = rest.find("(")
    if par < 0:
        return None
    kind = rest[:par]
    return name, rtype, kind, rest[par + 1:]


def _dot_flops(result_shape: str, rest: str, shapes: Dict[str, str]) -> float:
    """2 * prod(result dims) * contraction size for a dot op."""
    m = _SHAPE_RE.search(result_shape)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",") if d]
    out_elems = float(np.prod(dims)) if dims else 1.0
    mc = re.search(r"lhs_contracting_dims={([\d,]*)}", rest)
    ml = re.match(r"%?([\w.\-]+)", rest)
    k = 1.0
    if mc and ml and ml.group(1) in shapes:
        lhs = _SHAPE_RE.search(shapes[ml.group(1)])
        if lhs:
            ldims = [int(d) for d in lhs.group(2).split(",") if d]
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(ldims):
                    k *= ldims[int(ci)]
    return 2.0 * out_elems * k


def _trip_count(cond_lines: list) -> int:
    """Largest integer constant in the condition computation (jax-lowered
    loop counters run 0..N-1 against a constant bound N)."""
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.finditer(line):
            best = max(best, int(c.group(1)))
    return best


_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _crosses_pods(line: str, pod_size: int) -> bool:
    """Does this collective's replica grouping span pod boundaries?

    Device layout: mesh ("pod", "data", "model") with the pod axis leading,
    so pod(d) = d // pod_size.  Explicit-list groups are checked directly;
    iota-form groups ([G,S]<=[dims]T(perm)) are materialized exactly."""
    if pod_size <= 0:
        return False
    m = _GROUPS_LIST_RE.search(line)
    if m:
        for grp in re.findall(r"\{([^}]*)\}", m.group(1)):
            ids = [int(x) for x in grp.split(",") if x.strip()]
            if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                return True
        return False
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s) // pod_size
        return bool((groups.max(axis=1) != groups.min(axis=1)).any())
    return False


def loop_aware_analysis(hlo_text: str, pod_size: int = 0) -> Dict[str, Any]:
    comps = _split_computations(hlo_text)
    referenced = set()
    for lines in comps.values():
        for line in lines:
            for rx in (_CALLS_RE, _BODY_RE, _COND_RE):
                for m in rx.finditer(line):
                    referenced.add(m.group(1))
    entries = [c for c in comps if c not in referenced]

    totals = {"flops": 0.0, "writes": 0.0, "cross_pod": 0.0}
    by_kind = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}

    def _dus_update_bytes(rest, shapes):
        """For dynamic-update-slice, traffic is the update slice (operand
        1), not the full (in-place) buffer."""
        args = rest.split(")")[0]
        ops = re.findall(r"%([\w.\-]+)", args)
        if len(ops) >= 2:
            return _shape_bytes(shapes.get(ops[1], ""))
        return 0

    def _root_kind(comp):
        for line in comps.get(comp, []):
            if line.strip().startswith("ROOT"):
                p = _parse_op(line)
                if p:
                    return p[2], p[3], {q[0]: q[1] for q in
                                        filter(None, map(_parse_op,
                                                         comps[comp]))}
        return None, None, {}

    def walk(comp: str, mult: float, count_bytes: bool):
        lines = comps.get(comp)
        if lines is None:
            return
        shapes: Dict[str, str] = {}
        start_crosses: Dict[str, bool] = {}
        parsed = []
        for line in lines:
            p = _parse_op(line)
            if p:
                parsed.append((p, line))
                shapes[p[0]] = p[1]
                if p[2].endswith("-start"):
                    start_crosses[p[0]] = _crosses_pods(line, pod_size)
        for (name, rtype, kind, rest), line in parsed:
            if kind == "while":
                body = _BODY_RE.search(line)
                cond = _COND_RE.search(line)
                trip = (_trip_count(comps.get(cond.group(1), []))
                        if cond else 1)
                if body:
                    walk(body.group(1), mult * trip, count_bytes)
                continue
            if kind == "conditional":
                # descend into every branch (sum over branches — a
                # pessimistic upper bound; skip-vs-compute conditionals
                # have a trivial skip branch, so sum ~= compute branch)
                for m_ in _BRANCH_RE.finditer(line):
                    for grp in m_.groups():
                        if not grp:
                            continue
                        for name_ in re.findall(r"%?([\w.\-]+)",
                                                grp):
                            walk(name_, mult, count_bytes)
                if count_bytes:
                    totals["writes"] += mult * _shape_bytes(rtype)
                continue
            if kind in ("fusion", "call"):
                cm = _CALLS_RE.search(line)
                wb = _shape_bytes(rtype)
                if cm:
                    # fusion internals stay in registers: descend for dot
                    # flops only; write traffic = fusion result, except an
                    # in-place DUS root which writes only the update slice
                    walk(cm.group(1), mult, count_bytes=False)
                    rk, rrest, rshapes = _root_kind(cm.group(1))
                    if rk == "dynamic-update-slice":
                        wb = _dus_update_bytes(rrest, rshapes)
                if count_bytes:
                    totals["writes"] += mult * wb
                continue
            base = kind[:-6] if kind.endswith("-start") else (
                kind[:-5] if kind.endswith("-done") else kind)
            if base in _COLLECTIVES:
                if kind.endswith("-start"):
                    continue  # count the matching -done once
                rbytes = _shape_bytes(rtype)
                b = mult * rbytes * (2 if base == "all-reduce" else 1)
                by_kind[base] += b
                counts[base] += 1
                if pod_size:
                    if kind.endswith("-done"):
                        # groups live on the matching -start op
                        op0 = re.match(r"%?([\w.\-]+)", rest)
                        crosses = start_crosses.get(
                            op0.group(1) if op0 else "", False)
                    else:
                        crosses = _crosses_pods(line, pod_size)
                    if crosses:
                        totals["cross_pod"] += b
                if count_bytes:
                    totals["writes"] += mult * rbytes
                continue
            if base in ("dot", "convolution"):
                totals["flops"] += mult * _dot_flops(rtype, rest, shapes)
            if count_bytes and base not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast"):
                if base == "dynamic-update-slice":
                    totals["writes"] += mult * _dus_update_bytes(rest,
                                                                 shapes)
                else:
                    totals["writes"] += mult * _shape_bytes(rtype)

    for e in entries:
        walk(e, 1.0, count_bytes=True)
    # HBM traffic ~ writes + reads; every materialized buffer is written
    # once and read >= once downstream, so traffic ~= 2 x write bytes.
    return {"flops": totals["flops"], "bytes": 2.0 * totals["writes"],
            "collective_bytes": sum(by_kind.values()),
            "cross_pod_bytes": totals["cross_pod"],
            "by_kind": by_kind, "counts": counts}


def roofline_terms(compiled, *, peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW,
                   ici_bw: float = ICI_BW,
                   pod_size: int = 0) -> Dict[str, Any]:
    """The three roofline terms (seconds) + dominant bottleneck.

    Uses the loop-aware HLO walk (trip-count-corrected); the naive
    cost_analysis numbers are reported alongside for reference.
    ``pod_size``: devices per pod — enables cross-pod collective-byte
    classification (the scarce inter-pod links).
    """
    cost = cost_summary(compiled)
    la = loop_aware_analysis(compiled.as_text(), pod_size=pod_size)
    t_compute = la["flops"] / peak_flops
    t_memory = la["bytes"] / hbm_bw
    t_collective = la["collective_bytes"] / ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "hlo_flops": la["flops"],
        "hlo_bytes": la["bytes"],
        "collective_bytes": la["collective_bytes"],
        "cross_pod_bytes": la["cross_pod_bytes"],
        "cross_pod_s": la["cross_pod_bytes"] / ici_bw,
        "collective_by_kind": la["by_kind"],
        "collective_counts": la["counts"],
        "naive_cost_analysis": cost,
    }


def model_flops(n_params_active: int, n_tokens: int,
                mode: str = "train") -> float:
    """MODEL_FLOPS = 6 N D (train) or 2 N D (inference forward)."""
    c = 6.0 if mode == "train" else 2.0
    return c * n_params_active * n_tokens
