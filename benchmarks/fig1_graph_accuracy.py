"""Paper Fig. 1: approximation accuracy for Laplacians of random graphs as
a function of g = alpha * n * log2(n), undirected (G-transforms, top row)
and directed (T-transforms, bottom row), community / Erdos-Renyi / sensor
families.  Reduced sizes & seeds for CPU runtime; same metric (relative
squared Frobenius error, spectrum updated)."""
import numpy as np
import jax.numpy as jnp

from repro.core import build_fgft, laplacian, relative_error
from repro.graphs import (community_graph, erdos_renyi, sensor_graph,
                          directed_variant)
from .common import emit

SIZES = (64, 128)
ALPHAS = (0.5, 1.0, 2.0)
SEEDS = (0, 1, 2)
GENS = {"community": community_graph,
        "erdos_renyi": lambda n, seed: erdos_renyi(n, p=0.3, seed=seed),
        "sensor": sensor_graph}


def run(fast: bool = False):
    sizes = SIZES[:1] if fast else SIZES
    seeds = SEEDS[:2] if fast else SEEDS
    rows = []
    for fam, gen in GENS.items():
        for n in sizes:
            for directed in (False, True):
                for alpha in ALPHAS:
                    g = int(alpha * n * np.log2(n))
                    errs = []
                    for seed in seeds:
                        adj = gen(n, seed=seed)
                        if directed:
                            adj = directed_variant(adj, seed=seed)
                        lap = laplacian(adj)
                        f = build_fgft(jnp.asarray(lap), g,
                                       directed=directed, n_iter=3)
                        errs.append(relative_error(jnp.asarray(lap), f))
                    rows.append([fam, n, "directed" if directed else
                                 "undirected", alpha, float(np.mean(errs)),
                                 float(np.std(errs))])
    emit("fig1_graph_accuracy",
         rows, ["family", "n", "kind", "alpha", "rel_err_mean",
                "rel_err_std"])
    # invariant: error decreases with alpha for every (family, n, kind)
    for fam in GENS:
        for n in sizes:
            for kind in ("undirected", "directed"):
                sub = [r[4] for r in rows
                       if r[0] == fam and r[1] == n and r[2] == kind]
                assert sub[0] >= sub[-1], (fam, n, kind, sub)
    return rows


if __name__ == "__main__":
    run()
