"""Quickstart: factor a symmetric matrix and a graph Laplacian into fast
approximate eigenspaces (the paper's Algorithm 1), then use the result.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ApproxEigenbasis, approximate_symmetric,
                        approximate_general, build_fgft, laplacian,
                        relative_error, g_to_dense)
from repro.graphs import community_graph, directed_variant


def main():
    rng = np.random.default_rng(0)

    # --- 0. the one-stop batched facade (mirrored in README.md) ----------
    xs = rng.standard_normal((4, 32, 32)).astype(np.float32)
    mats = jnp.asarray(xs + np.swapaxes(xs, 1, 2))     # (B, n, n) batch
    basis = ApproxEigenbasis.fit(mats, num_transforms=128, n_iter=3)
    signals = jnp.asarray(rng.standard_normal((4, 8, 32)).astype(np.float32))
    coeffs = basis.apply(signals, inverse=True)        # Ubar^T x, per matrix
    filtered = basis.project(signals, h=lambda lam: 1.0 / (1.0 + lam))
    rel = basis.objective / jnp.sum(mats * mats, axis=(1, 2))
    print(f"[batched]   B=4 matrices in one jit: rel errors "
          f"{np.round(np.asarray(rel), 4)}; coeffs {coeffs.shape}, "
          f"filtered {filtered.shape}")

    # --- 1. symmetric matrix -> G-transform factorization ----------------
    n = 64
    x = rng.standard_normal((n, n)).astype(np.float32)
    s = jnp.asarray(x @ x.T)                       # PSD example
    g = 2 * n * int(np.log2(n))                    # alpha = 2
    factors, sbar, info = approximate_symmetric(s, g=g, n_iter=4)
    rel = float(info["objective"]) / float(jnp.sum(s * s))
    print(f"[symmetric] n={n} g={g}: relative error {rel:.4f} "
          f"({int(info['iterations'])} sweeps)")
    u = g_to_dense(factors, n)
    orth = float(jnp.abs(u @ u.T - jnp.eye(n)).max())
    print(f"[symmetric] Ubar orthonormality defect: {orth:.2e}; "
          f"matvec cost 6g = {6 * g} flops vs dense 2n^2 = {2 * n * n}")

    # --- 2. unsymmetric matrix -> T-transform factorization --------------
    c = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    tf, cbar, tinfo = approximate_general(c, m=g, n_iter=4)
    rel_t = float(tinfo["objective"]) / float(jnp.sum(c * c))
    print(f"[general]   n={n} m={g}: relative error {rel_t:.4f}")

    # --- 3. fast graph Fourier transform ---------------------------------
    adj = community_graph(96, seed=1)
    lap = laplacian(adj)
    fgft = build_fgft(jnp.asarray(lap), num_transforms=96 * 7 * 2,
                      directed=False, n_iter=3)
    print(f"[fgft undirected] rel error "
          f"{relative_error(jnp.asarray(lap), fgft):.4f}, "
          f"{fgft.flops_per_matvec()} flops/matvec")
    signal = jnp.asarray(rng.standard_normal((4, 96)).astype(np.float32))
    coeffs = fgft.analysis(signal)             # Ubar^T x
    smooth = fgft.filter(signal, lambda lam: 1.0 / (1.0 + lam))
    back = fgft.synthesis(coeffs)
    print(f"[fgft] roundtrip error {float(jnp.abs(back - signal).max()):.2e}"
          f", low-pass energy ratio "
          f"{float(jnp.sum(smooth ** 2) / jnp.sum(signal ** 2)):.3f}")

    # --- 4. directed graph -> T-transform FGFT ---------------------------
    dadj = directed_variant(adj, seed=2)
    dlap = laplacian(dadj)
    dfgft = build_fgft(jnp.asarray(dlap), num_transforms=96 * 7 * 2,
                       directed=True, n_iter=3)
    print(f"[fgft directed]   rel error "
          f"{relative_error(jnp.asarray(dlap), dfgft):.4f}, "
          f"{dfgft.flops_per_matvec()} flops/matvec")


if __name__ == "__main__":
    main()
