"""Benchmark harness: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig1,fig5]
"""
import argparse
import sys
import time
import traceback

from . import (fig1_graph_accuracy, fig2_fgft_comparison, fig4_vs_directU,
               fig5_random_matrices, fig6_speedup, fig7_batched,
               kernels_micro, roofline)

BENCHES = {
    "fig1": fig1_graph_accuracy.run,
    "fig2_fig3": fig2_fgft_comparison.run,
    "fig4": fig4_vs_directU.run,
    "fig5": fig5_random_matrices.run,
    "fig6": fig6_speedup.run,
    "fig7": fig7_batched.run,
    "kernels": kernels_micro.run,
    "roofline": roofline.run,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes/seeds for smoke runs")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benches")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))
    failures = 0
    for name, fn in BENCHES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn(fast=args.fast)
            print(f"[{name} done in {time.time() - t0:.1f}s]")
        except Exception:  # noqa: BLE001 — report all benches
            failures += 1
            print(f"[{name} FAILED]")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
