"""The paper's application end-to-end: build fast GFTs for all three
synthetic graph families (+ a real-graph stand-in), compare against
truncated Jacobi, and run spectral filtering through the staged kernels.

  PYTHONPATH=src python examples/fgft_graph.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ApproxEigenbasis, build_fgft, laplacian,
                        relative_error, truncated_jacobi, g_objective)
from repro.graphs import (community_graph, erdos_renyi, sensor_graph,
                          real_graph_standin)


def batched_demo(n: int, g: int):
    """All three graph families factored in ONE jit (the batched engine),
    then filtered together through one batched fused-kernel dispatch."""
    gens = (("community", community_graph),
            ("erdos", lambda n, seed: erdos_renyi(n, 0.3, seed)),
            ("sensor", sensor_graph))
    laps = np.stack([laplacian(gen(n, seed=0)) for _, gen in gens])
    basis = ApproxEigenbasis.fit(jnp.asarray(laps), g, n_iter=3)
    rel = np.asarray(basis.objective) / (laps * laps).sum(axis=(1, 2))
    print("\nbatched engine (one jit for all graphs):")
    for (name, _), r in zip(gens, rel):
        print(f"  {name:12s} rel error {r:.5f}")
    signals = jnp.asarray(np.random.default_rng(7).standard_normal(
        (len(gens), 4, n)).astype(np.float32))
    smooth = basis.project(signals, h=lambda lam: 1.0 / (1.0 + lam))
    print(f"  one dispatch low-pass filtered {smooth.shape[0]} graphs x "
          f"{smooth.shape[1]} signals")


def main():
    n = 96
    alpha = 2
    g = int(alpha * n * np.log2(n))
    print(f"n={n}, g = {alpha} * n log2 n = {g}\n")
    print(f"{'graph':12s} {'proposed':>10s} {'jacobi':>10s} {'stages':>7s}")
    for name, gen in (("community", community_graph),
                      ("erdos", lambda n, seed: erdos_renyi(n, 0.3, seed)),
                      ("sensor", sensor_graph)):
        lap = laplacian(gen(n, seed=0))
        s = jnp.asarray(lap)
        den = float((lap * lap).sum())
        fgft = build_fgft(s, g, directed=False, n_iter=3)
        fj, sj = truncated_jacobi(s, g=g)
        ej = float(g_objective(s, fj, sj)) / den
        print(f"{name:12s} {relative_error(s, fgft):10.5f} {ej:10.5f} "
              f"{fgft.fwd.num_stages:7d}")

    # real-graph stand-in (subsampled for CPU)
    adj = real_graph_standin("email")[:192, :192]
    lap = laplacian(adj)
    s = jnp.asarray(lap)
    fgft = build_fgft(s, int(2 * 192 * np.log2(192)), directed=False,
                      n_iter=3)
    print(f"{'email[:192]':12s} {relative_error(s, fgft):10.5f}")

    # spectral filtering demo: denoise a piecewise-constant signal
    rng = np.random.default_rng(3)
    lap = laplacian(community_graph(n, seed=5))
    fgft = build_fgft(jnp.asarray(lap), g, directed=False, n_iter=3)
    base = (rng.integers(0, 2, n) * 2.0 - 1.0).astype(np.float32)
    noisy = base + 0.5 * rng.standard_normal(n).astype(np.float32)
    denoised = fgft.filter(jnp.asarray(noisy[None]),
                           lambda lam: 1.0 / (1.0 + 2.0 * lam))[0]
    err_before = float(((noisy - base) ** 2).mean())
    err_after = float(((np.asarray(denoised) - base) ** 2).mean())
    print(f"\nlow-pass denoising MSE: {err_before:.3f} -> {err_after:.3f} "
          f"(O(n log n) filter via staged kernels)")

    batched_demo(n, g)


if __name__ == "__main__":
    main()
