"""Graph spectral operations served through the batched FGFT engine.

The application layer on top of ``ApproxEigenbasis`` (DESIGN.md §8):
filter banks (filters.py) dispatched through the fused Pallas bank kernel
(kernels/spectral.py), top-k coefficient compression (compress.py), and
the Chebyshev matched-FLOPs baseline (chebyshev.py).
"""
from .filters import (RESPONSES, Response, SpectralFilter,
                      SpectralFilterBank, bandpass, hammond_bank,
                      hammond_kernel, heat, highpass, lowpass,
                      named_responses, response_lipschitz, tikhonov,
                      wavelet_scales)
from .compress import Compressed, compress, compression_error, \
    topk_coefficients
from .chebyshev import (chebyshev_apply, chebyshev_coefficients,
                        chebyshev_filter, estimate_lmax, matched_degree)
