"""DEPRECATED compatibility shims over the plan layer (kernels/plan.py).

Every function here is a thin alias that builds an ``ApplyPlan`` and
runs its cached program — kept so existing call sites and notebooks
survive, but new code should construct plans directly: the plan is the
ONE place family/mode/batching/cut/backend/precision dispatch is wired
(DESIGN.md §13), and plan programs are process-cached so hot-swapped
tables with unchanged shapes never recompile (DESIGN.md §11).

Shape/dtype conventions (unchanged; DESIGN.md §4):
  * single-matrix staged tables are (S, P) — S conflict-free stages of
    width P (core/staging.py); batched tables carry a leading matrix-
    batch dim: (B, S, P) (DESIGN.md §7).
  * signals put coordinates on the LAST axis: x is (..., n) for the
    single-matrix ops and (B, ..., n) for the batched ops.
  * tables are stored f32 by default; the apply casts them to
    ``x.dtype`` (bf16 signals are supported — see tests/test_kernels.py
    dtype sweeps).  For bf16 TABLE storage with f32 accumulation use a
    plan with ``precision="bf16"`` (core/staging.py::with_precision).

Ragged fleets (DESIGN.md §10): a masked (size-bucketed) fit's tables
act as the identity on each matrix's padding coordinates, so these ops
need no extra arguments for ragged batches.  Anytime prefixes
(DESIGN.md §9): every op takes a static ``num_stages``; the fused
operators cut both legs consistently, the plain applies additionally
take ``keep`` ("tail" for G fwd / T inverse tables, "head" for
G adjoint / T fwd — kernels/plan.py::leg_orientation).

The batched/unbatched wrapper pairs collapse onto the same plans (the
plan infers batching from the table rank); both names remain as
deprecated aliases.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.staging import StagedG, StagedT, pack_g_pair, pack_t_pair
from repro.core.types import GFactors, TFactors
from .plan import ApplyPlan


def _apply(staged, x, backend, interpret, num_stages, keep):
    return ApplyPlan.for_staged(
        staged, mode="apply", backend=backend, interpret=interpret,
        num_stages=num_stages, keep=keep).apply(staged, x)


def _operator(fwd, bwd, diag, x, backend, interpret, num_stages):
    return ApplyPlan.for_staged(
        fwd, mode="operator", backend=backend, interpret=interpret,
        num_stages=num_stages).operator(fwd, bwd, diag, x)


def _bank(fwd, bwd, gains, x, backend, interpret, num_stages):
    return ApplyPlan.for_staged(
        fwd, mode="bank", backend=backend, interpret=interpret,
        num_stages=num_stages).bank(fwd, bwd, gains, x)


def g_apply(staged: StagedG, x: jnp.ndarray, backend: str = "xla",
            interpret: bool = True, num_stages: int | None = None,
            keep: str = "head") -> jnp.ndarray:
    """Deprecated shim: y = Ubar x — the product of extended Givens
    transforms, eq. (5).  ``staged``: (S, P) tables; ``x``: (..., n),
    any float dtype.  Cost 6g flops (paper Table 1), or 6g' under a
    ``num_stages`` prefix cut."""
    return _apply(staged, x, backend, interpret, num_stages, keep)


def t_apply(staged: StagedT, x: jnp.ndarray, backend: str = "xla",
            interpret: bool = True, num_stages: int | None = None,
            keep: str = "head") -> jnp.ndarray:
    """Deprecated shim: y = Tbar x — the product of scaling/shear
    transforms, eq. (10).  Cost 1 flop per scaling and 2 per shear."""
    return _apply(staged, x, backend, interpret, num_stages, keep)


def sym_operator(fwd: StagedG, adj: StagedG, diag: jnp.ndarray,
                 x: jnp.ndarray, backend: str = "xla",
                 interpret: bool = True,
                 num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: Sbar x = Ubar diag(d) Ubar^T x — eq. (2) as a
    fused operator (one VMEM round trip on the pallas backend;
    ``num_stages`` truncates both legs to the same component prefix)."""
    return _operator(fwd, adj, diag, x, backend, interpret, num_stages)


def gen_operator(fwd: StagedT, inv: StagedT, diag: jnp.ndarray,
                 x: jnp.ndarray, backend: str = "xla",
                 interpret: bool = True,
                 num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: Cbar x = Tbar diag(d) Tbar^{-1} x — eq. (7) as
    a fused operator."""
    return _operator(fwd, inv, diag, x, backend, interpret, num_stages)


def batched_sym_operator(fwd: StagedG, adj: StagedG, diag: jnp.ndarray,
                         x: jnp.ndarray, backend: str = "xla",
                         interpret: bool = True,
                         num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: y[b] = Ubar_b diag(d_b) Ubar_b^T x[b] — tables
    (B, S, P), ``diag`` (B, n), ``x`` (B, ..., n); one dispatch serves
    the whole fleet (DESIGN.md §7)."""
    return _operator(fwd, adj, diag, x, backend, interpret, num_stages)


def batched_gen_operator(fwd: StagedT, inv: StagedT, diag: jnp.ndarray,
                         x: jnp.ndarray, backend: str = "xla",
                         interpret: bool = True,
                         num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: y[b] = Tbar_b diag(d_b) Tbar_b^{-1} x[b]."""
    return _operator(fwd, inv, diag, x, backend, interpret, num_stages)


def sym_filter_bank(fwd: StagedG, adj: StagedG, gains: jnp.ndarray,
                    x: jnp.ndarray, backend: str = "xla",
                    interpret: bool = True,
                    num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: y[f] = Ubar diag(gains_f) Ubar^T x for a bank of
    F filters — gains (F, n), x (..., n) -> (F, ..., n); the analysis
    leg runs once and the pallas path fuses the whole bank into one
    kernel launch (DESIGN.md §8)."""
    return _bank(fwd, adj, gains, x, backend, interpret, num_stages)


def gen_filter_bank(fwd: StagedT, inv: StagedT, gains: jnp.ndarray,
                    x: jnp.ndarray, backend: str = "xla",
                    interpret: bool = True,
                    num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: the directed (T-family) filter bank."""
    return _bank(fwd, inv, gains, x, backend, interpret, num_stages)


def batched_sym_filter_bank(fwd: StagedG, adj: StagedG, gains: jnp.ndarray,
                            x: jnp.ndarray, backend: str = "xla",
                            interpret: bool = True,
                            num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: per-matrix banks — tables (B, S, P), gains
    (B, F, n), x (B, ..., n) -> (B, F, ..., n)."""
    return _bank(fwd, adj, gains, x, backend, interpret, num_stages)


def batched_gen_filter_bank(fwd: StagedT, inv: StagedT, gains: jnp.ndarray,
                            x: jnp.ndarray, backend: str = "xla",
                            interpret: bool = True,
                            num_stages: int | None = None) -> jnp.ndarray:
    """Deprecated shim: directed per-matrix banks."""
    return _bank(fwd, inv, gains, x, backend, interpret, num_stages)


def batched_g_apply(staged: StagedG, x: jnp.ndarray,
                    backend: str = "xla", interpret: bool = True,
                    num_stages: int | None = None,
                    keep: str = "head") -> jnp.ndarray:
    """Deprecated shim: y[b] = Ubar_b x[b] — tables (B, S, P)."""
    return _apply(staged, x, backend, interpret, num_stages, keep)


def batched_t_apply(staged: StagedT, x: jnp.ndarray,
                    backend: str = "xla", interpret: bool = True,
                    num_stages: int | None = None,
                    keep: str = "head") -> jnp.ndarray:
    """Deprecated shim: y[b] = Tbar_b x[b] — tables (B, S, P)."""
    return _apply(staged, x, backend, interpret, num_stages, keep)


def stage_g(factors: GFactors):
    """Convenience: (forward, adjoint) staged forms of one G-chain
    (one scheduling pass; the adjoint is a stage mirror)."""
    return pack_g_pair(factors)


def stage_t(factors: TFactors, n: int):
    """Convenience: (forward, inverse) staged forms of one T-chain."""
    return pack_t_pair(factors, n)
