"""Benchmark harness: one module per paper table/figure + micro/roofline.

Benchmarks are DISCOVERED, not hand-registered: every ``*.py`` module in
this package (except this runner and ``common.py``) that exposes a
``run(fast: bool)`` callable is picked up automatically, so a new
``figN_*.py`` is runnable the moment the file exists.  ``fig*`` modules
are addressable by their short prefix (``--only fig8``) or full stem.

  PYTHONPATH=src python -m benchmarks.run --all [--fast]
  PYTHONPATH=src python -m benchmarks.run --only fig1,fig8 --fast
  PYTHONPATH=src python -m benchmarks.run --list

``--json-dir DIR`` additionally writes one ``BENCH_<name>.json`` per
benchmark (rows, elapsed seconds, pass/fail) so CI can upload the results
as workflow artifacts and performance trajectories survive the run.
"""
import argparse
import importlib
import json
import math
import os
import pathlib
import sys
import time
import traceback

_SKIP = {"run", "common", "__init__"}


def discover():
    """Returns (benches, aliases).

    ``benches``: full module stem -> run callable, for every benchmark
    module in the package.  ``aliases``: short ``figN`` prefix -> full
    stem, registered only when the prefix is unambiguous and is not
    itself a module name (a real ``fig9.py`` always wins over an alias).
    """
    benches = {}
    here = pathlib.Path(__file__).parent
    for path in sorted(here.glob("*.py")):
        stem = path.stem
        if stem in _SKIP or stem.startswith("_"):
            continue
        mod = importlib.import_module(f".{stem}", __package__)
        fn = getattr(mod, "run", None)
        if not callable(fn):
            raise RuntimeError(
                f"benchmark module {stem}.py has no run(fast) entry point")
        benches[stem] = fn
    aliases = {}
    for stem in benches:
        short = stem.split("_")[0]
        if stem.startswith("fig") and short != stem and short not in benches:
            # ambiguous prefixes (two figN_* modules) get no alias
            aliases[short] = None if short in aliases else stem
    return benches, {k: v for k, v in aliases.items() if v is not None}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes/seeds for smoke runs")
    ap.add_argument("--all", action="store_true",
                    help="run every discovered benchmark")
    ap.add_argument("--only", default="",
                    help="comma-separated subset (short fig aliases ok)")
    ap.add_argument("--list", action="store_true",
                    help="print discovered benchmarks and exit")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<name>.json result files here "
                         "(created if missing)")
    args = ap.parse_args(argv)
    benches, aliases = discover()
    if args.list:
        for name in sorted(benches):
            print(name)
        return 0
    selected = set()
    for token in filter(None, args.only.split(",")):
        if token in benches:
            selected.add(token)
        elif token in aliases:
            selected.add(aliases[token])
        else:
            ap.error(f"unknown benchmark {token!r}; discovered: "
                     f"{sorted(benches)} (aliases: {sorted(aliases)})")
    if not selected and not args.all:
        ap.error("pass --all to run every benchmark, or --only <names>")
    json_dir = None
    if args.json_dir:
        json_dir = pathlib.Path(args.json_dir)
        json_dir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for name in sorted(benches):
        if selected and name not in selected:
            continue
        t0 = time.time()
        record = {"benchmark": name, "fast": bool(args.fast)}
        try:
            rows = benches[name](fast=args.fast)
            from . import common
            record.update(status="pass", rows=_jsonable(rows),
                          columns=common.LAST_HEADERS.get(name))
            print(f"[{name} done in {time.time() - t0:.1f}s]")
        except Exception as exc:  # noqa: BLE001 — report all benches
            failures += 1
            # a failed gate is exactly when the measured rows matter most;
            # benchmarks attach them to the raised error (gate_assert)
            record.update(status="fail", error=repr(exc),
                          rows=_jsonable(getattr(exc, "bench_rows", None)))
            print(f"[{name} FAILED]")
            traceback.print_exc()
        record["elapsed_s"] = round(time.time() - t0, 3)
        if json_dir is not None:
            (json_dir / f"BENCH_{name}.json").write_text(
                json.dumps(record, indent=1))
        _export_trace(name)
    _export_metrics()
    return 1 if failures else 0


def _obs_dir():
    out = os.environ.get("REPRO_METRICS_DIR")
    return pathlib.Path(out) if out else None


def _export_trace(name):
    """When ``$REPRO_METRICS_DIR`` is set (CI), drop ``trace_<bench>.json``
    next to the BENCH artifacts; the tracer is cleared after each export
    so every file holds exactly one benchmark's spans."""
    out = _obs_dir()
    if out is None:
        return
    from repro import obs
    obs.export_trace(out / f"trace_{name}.json")
    obs.default_tracer().clear()


def _export_metrics():
    """One merged ``metrics.json``/``metrics.prom`` per PROCESS (the
    registry is cumulative, so a per-benchmark merge inside one process
    would double-count); CI's one-process-per-benchmark loop accumulates
    the file across processes via the merge."""
    out = _obs_dir()
    if out is None:
        return
    from repro import obs
    obs.export_metrics(out, merge=True)


def gate_assert(cond, msg, rows=None):
    """Benchmark gate: like assert, but a failure carries the measured
    rows so the BENCH_*.json artifact records them (see main())."""
    if not cond:
        err = AssertionError(msg)
        err.bench_rows = rows
        raise err


def _jsonable(obj):
    """Coerce benchmark return values (numpy scalars/arrays, tuples) into
    strict JSON: non-finite floats become None (json.dumps would emit the
    non-standard NaN/Infinity tokens), non-coercible values their repr."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return _jsonable(obj.item())   # numpy scalar: re-check finiteness
    if hasattr(obj, "tolist"):
        return _jsonable(obj.tolist())
    return repr(obj)


if __name__ == "__main__":
    sys.exit(main())
