"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init and only then calls it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") single pod; 2x16x16 ("pod","data","model")
    for the 512-chip two-pod configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (CPU smoke / single host)."""
    n = len(jax.devices())
    if model_axis <= 0 or n % model_axis != 0:
        raise ValueError(
            f"make_local_mesh: {n} visible device(s) cannot be factored "
            f"into a model axis of {model_axis} (need model_axis >= 1 and "
            f"{n} % model_axis == 0)")
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
