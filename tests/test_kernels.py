"""Pallas kernels (interpret mode) vs the pure-jnp oracle: shape/dtype
sweeps as required for every kernel."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (approximate_symmetric, approximate_general,
                        pack_g, pack_g_adjoint, pack_t, pack_t_inverse)
from repro.kernels import ref
from repro.kernels import butterfly as bf
from repro.kernels import shear as sh
from repro.kernels.plan import ApplyPlan


def _staged_g(n, g, seed=0):
    x = np.random.default_rng(seed).standard_normal((n, n)).astype(np.float32)
    f, sbar, _ = approximate_symmetric(jnp.asarray(x + x.T), g=g, n_iter=1)
    return pack_g(f), pack_g_adjoint(f), sbar


def _staged_t(n, m, seed=0):
    c = np.random.default_rng(seed).standard_normal((n, n)).astype(np.float32)
    f, cbar, _ = approximate_general(jnp.asarray(c), m=m, n_iter=1)
    return pack_t(f, n), pack_t_inverse(f, n), cbar


SHAPES = [(1, 16), (7, 32), (64, 48), (130, 16)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("b,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_butterfly_kernel_sweep(b, n, dtype):
    fwd, _, _ = _staged_g(n, 2 * n, seed=b)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((b, n)),
                    dtype)
    want = ref.staged_g_apply(fwd, x)
    got = bf.butterfly_apply(fwd, x, interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.slow
def test_shear_kernel_sweep(b, n, dtype):
    fwd, _, _ = _staged_t(n, 2 * n, seed=b)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((b, n)), dtype)
    want = ref.staged_t_apply(fwd, x)
    got = sh.shear_apply(fwd, x, interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n", [(4, 16), (33, 32)])
def test_fused_sym_kernel(b, n):
    fwd, adj, sbar = _staged_g(n, 3 * n, seed=7)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((b, n)),
                    jnp.float32)
    want = ref.sym_operator_apply(fwd, adj, sbar, x)
    got = bf.sym_operator_apply(fwd, adj, sbar, x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,n", [(4, 16), (33, 32)])
@pytest.mark.slow
def test_fused_gen_kernel(b, n):
    fwd, inv, cbar = _staged_t(n, 3 * n, seed=8)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((b, n)),
                    jnp.float32)
    want = ref.gen_operator_apply(fwd, inv, cbar, x)
    got = sh.gen_operator_apply(fwd, inv, cbar, x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_plan_backend_switch_and_nd_shapes():
    fwd, adj, sbar = _staged_g(16, 32, seed=9)
    x = jnp.asarray(np.random.default_rng(5).standard_normal((3, 5, 16)),
                    jnp.float32)

    def apply(backend):
        return ApplyPlan.for_staged(fwd, mode="apply",
                                    backend=backend).apply(fwd, x)

    y_x, y_p = apply("xla"), apply("pallas")
    assert y_x.shape == x.shape
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p), atol=1e-6)
    with pytest.raises(ValueError):
        apply("cuda")


def test_block_b_tiling_boundaries():
    """Batch not divisible by block_b exercises the grid edge."""
    fwd, _, _ = _staged_g(16, 32, seed=10)
    x = jnp.asarray(np.random.default_rng(6).standard_normal((130, 16)),
                    jnp.float32)
    got = bf.butterfly_apply(fwd, x, block_b=64, interpret=True)
    want = ref.staged_g_apply(fwd, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------------------
# Anytime prefix parity (DESIGN.md §9): the Pallas kernels must match the
# XLA oracle at EVERY exact tier boundary, for both table orientations.
# ---------------------------------------------------------------------------


def _tier_boundaries(staged):
    """All exact (num_stages,) boundaries except the trivial empty cut."""
    return [int(s) for s, k in np.asarray(staged.cuts) if k > 0]


@pytest.mark.slow
def test_butterfly_prefix_parity_all_tiers():
    fwd, adj, _ = _staged_g(24, 60, seed=11)
    x = jnp.asarray(np.random.default_rng(7).standard_normal((9, 24)),
                    jnp.float32)
    for s in _tier_boundaries(fwd):
        for staged, keep in ((fwd, "tail"), (adj, "head")):
            want = ref.staged_g_apply(staged, x, num_stages=s, keep=keep)
            got = bf.butterfly_apply(staged, x, interpret=True,
                                     num_stages=s, keep=keep)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_shear_prefix_parity_all_tiers():
    fwd, inv, _ = _staged_t(20, 40, seed=12)
    x = jnp.asarray(np.random.default_rng(8).standard_normal((6, 20)),
                    jnp.float32)
    for s in _tier_boundaries(fwd):
        for staged, keep in ((fwd, "head"), (inv, "tail")):
            want = ref.staged_t_apply(staged, x, num_stages=s, keep=keep)
            got = sh.shear_apply(staged, x, interpret=True,
                                 num_stages=s, keep=keep)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_fused_prefix_parity_all_tiers():
    fwd, adj, sbar = _staged_g(16, 48, seed=13)
    x = jnp.asarray(np.random.default_rng(9).standard_normal((5, 16)),
                    jnp.float32)
    for s in _tier_boundaries(fwd):
        want = ref.sym_operator_apply(fwd, adj, sbar, x, num_stages=s)
        got = bf.sym_operator_apply(fwd, adj, sbar, x, interpret=True,
                                    num_stages=s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    tfwd, tinv, cbar = _staged_t(16, 48, seed=14)
    for s in _tier_boundaries(tfwd):
        want = ref.gen_operator_apply(tfwd, tinv, cbar, x, num_stages=s)
        got = sh.gen_operator_apply(tfwd, tinv, cbar, x, interpret=True,
                                    num_stages=s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_plan_prefix_backend_parity_and_bank():
    """plan-level switch: xla and pallas agree at a mid-ladder boundary
    for the plain, fused and filter-bank paths."""
    from repro.core.staging import select_cut
    fwd, adj, sbar = _staged_g(16, 32, seed=15)
    s, _ = select_cut(fwd, fraction=0.5)
    x = jnp.asarray(np.random.default_rng(10).standard_normal((2, 3, 16)),
                    jnp.float32)

    def plan(mode, backend, keep="head"):
        return ApplyPlan.for_staged(fwd, mode=mode, backend=backend,
                                    num_stages=s, keep=keep)

    y_x = plan("apply", "xla", keep="tail").apply(fwd, x)
    y_p = plan("apply", "pallas", keep="tail").apply(fwd, x)
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p), atol=1e-6)
    o_x = plan("operator", "xla").operator(fwd, adj, sbar, x)
    o_p = plan("operator", "pallas").operator(fwd, adj, sbar, x)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_p), atol=1e-5)
    gains = jnp.asarray(np.random.default_rng(11).standard_normal(
        (3, 16)), jnp.float32)
    b_x = plan("bank", "xla").bank(fwd, adj, gains, x[0])
    b_p = plan("bank", "pallas").bank(fwd, adj, gains, x[0])
    np.testing.assert_allclose(np.asarray(b_x), np.asarray(b_p), atol=1e-5)
