"""Roofline report for the staged-table serving kernels + autotune prior.

Replaces the old dry-run reader, which silently no-oped unless a
``results/dryrun`` directory existed.  Per (family, n) this builds a
real staged table pair and emits its geometry (S stages x P lanes), the
EXACT bytes one fused operator dispatch touches (two int32 index tables
plus the family's value tables per leg, the signal block in/out and the
diagonal), the paper-model FLOPs (Table 1: 6 per Givens entry, <= 2 per
shear/scale entry), the resulting arithmetic intensity, and a measured
micro timing of the fused operator plan (kernels/plan.py).

The same analytic model then seeds the persisted autotune cache
(kernels/autotune.py) with ``source="prior"`` entries: a
``prior_block_b`` tile per plan key the grid covers, plus the
stage-chunk depth-overhead scan (finer cut ladders pack deeper — the
scan records the finest granularity that stays within ~10% extra
depth).  Measurements (e.g. fig13's tuner pass) refine priors to
``source="measured"``; a prior never overwrites a measurement.
"""
import numpy as np

from .common import emit, time_call

#: per-entry FLOPs of one staged table entry (paper Table 1): a Givens
#: rotation costs 6; shears cost 2 and scalings 1 — the shear bound
#: keeps padded entries honest (the kernels execute those too).
ENTRY_FLOPS = {"sym": 6, "general": 2}
#: value tables per entry next to the two int32 index tables
#: (c/s/sigma for G, alpha/beta for T — core/staging.py).
VALUE_TABLES = {"sym": 3, "general": 2}

#: depth overhead budget for the chunk-granularity prior: the finest
#: ladder whose packed depth stays within this fraction of the 1-chunk
#: schedule wins.
CHUNK_OVERHEAD_BUDGET = 0.10


def _chain(family, n, g, seed=0):
    """One fitted factor chain + spectrum (n_iter=1: the roofline cares
    about table geometry and timings, not approximation quality)."""
    import jax.numpy as jnp
    from repro.core import approximate_general, approximate_symmetric
    a = np.random.default_rng(seed).standard_normal((n, n)).astype(
        np.float32)
    if family == "sym":
        factors, spec, _ = approximate_symmetric(jnp.asarray(a + a.T),
                                                 g=g, n_iter=1)
    else:
        factors, spec, _ = approximate_general(jnp.asarray(a), m=g,
                                               n_iter=1)
    return factors, spec


def _pack(family, factors, n, num_chunks=None):
    from repro.core import staging
    cuts = None
    if num_chunks is not None:
        g = (factors.g if family == "sym" else len(factors.kind))
        cuts = staging.default_cut_ladder(int(g), num_chunks).tolist()
    if family == "sym":
        return staging.pack_g_pair(factors, cuts=cuts)
    return staging.pack_t_pair(factors, n, cuts=cuts)


def _seed_priors(family, n, s, p, autotune, plan_cls):
    """Analytic ``source="prior"`` tile entries for every plan key this
    (family, n) geometry serves; returns the operator-mode prior for the
    report row."""
    values = VALUE_TABLES[family]
    out = None
    for mode in ("apply", "operator", "bank"):
        legs = 1 if mode == "apply" else 2
        bb = autotune.prior_block_b(n, s, p, values=values, legs=legs)
        for batched in (False, True):
            plan = plan_cls(family=family, mode=mode, n=n,
                            batched=batched)
            autotune.record(autotune.plan_key(plan), source="prior",
                            block_b=bb)
        if mode == "operator":
            out = bb
    return out


def _chunk_prior(family, factors, n, autotune):
    """Depth-overhead scan over the cut-ladder granularities: packs the
    SAME chain at each candidate and records the finest ladder within
    the depth budget."""
    depths = {}
    for k in autotune.CHUNK_CANDIDATES:
        fwd, _ = _pack(family, factors, n, num_chunks=k)
        depths[k] = int(fwd.idx_i.shape[-2])
    base = max(depths[min(depths)], 1)
    overhead = {str(k): round(d / base - 1.0, 4)
                for k, d in depths.items()}
    best = max(k for k, d in depths.items()
               if d / base - 1.0 <= CHUNK_OVERHEAD_BUDGET)
    autotune.record(autotune.chunk_key(family, n), source="prior",
                    num_chunks=int(best), depth_overhead=overhead)
    return best


def run(fast: bool = False):
    import jax.numpy as jnp
    from repro.kernels import autotune
    from repro.kernels.plan import ApplyPlan

    ns = (32, 64) if fast else (32, 64, 128)
    signal_rows = 16 if fast else 64
    rng = np.random.default_rng(0)
    rows = []
    for family in ("sym", "general"):
        for n in ns:
            g = int(2 * n * np.log2(n))
            factors, spec = _chain(family, n, g)
            fwd, bwd = _pack(family, factors, n)
            s, p = fwd.idx_i.shape
            values = VALUE_TABLES[family]
            # one fused operator dispatch: both legs' tables + signal
            # in/out + the diagonal, all touched exactly once
            table_bytes = 2 * s * p * (2 * 4 + values * 4)
            moved_bytes = table_bytes + (2 * signal_rows * n + n) * 4
            flops = signal_rows * (2 * s * p * ENTRY_FLOPS[family] + n)
            plan = ApplyPlan(family=family, mode="operator", n=n)
            prog = plan.program()
            ft, bt = plan.prepare(fwd), plan.prepare(bwd)
            x = jnp.asarray(rng.standard_normal(
                (signal_rows, n)).astype(np.float32))
            d = jnp.asarray(spec)
            t = time_call(prog, ft, bt, d, x)
            bb = _seed_priors(family, n, s, p, autotune, ApplyPlan)
            chunks = _chunk_prior(family, factors, n, autotune)
            rows.append([
                family, n, g, s, p,
                round(table_bytes / 1024.0, 2),
                round(flops / max(moved_bytes, 1), 3),
                round(t * 1e6, 1),
                round(flops / max(t, 1e-12) / 1e9, 3),
                bb, chunks,
            ])
    emit("roofline (fused operator dispatch; bytes model seeds the "
         "autotune prior)",
         rows, ["family", "n", "g", "stages", "lanes", "table_kb",
                "flops_per_byte", "xla_us", "gflops_per_s",
                "prior_block_b", "prior_chunks"])
    print(f"[roofline] autotune priors -> {autotune.cache_path()}")
    return rows


if __name__ == "__main__":
    run()
