"""Versioned hot-swap under real concurrency (DESIGN.md §11/§12): N
threads hammer the serving path while ``maintain()`` refits and swaps
underneath.  Every response must be internally consistent — produced by
exactly ONE serving version, bitwise equal to that version's
single-threaded answer (no torn tier tables), no exceptions anywhere —
and shutdown must return the thread count to baseline.

Synchronization discipline: the assertions are all on recorded VALUES
(versions, outputs, counters), never on timing — threads are joined
before anything is checked, so nothing here can flake on a slow box."""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.launch.serve import FGFTServeEngine
from repro.launch.service import (AsyncFGFTService, closed_loop_load,
                                  shutdown_all_services)


def _alive_non_daemon():
    return {t for t in threading.enumerate()
            if t.is_alive() and not t.daemon}


@pytest.fixture()
def dyn_fleet():
    """(engine, stream): a 2-graph dynamic symmetric fleet whose refresh
    threshold is ~0, so every churn round forces a version swap."""
    from repro.dynamic import GraphStream, RefitPolicy
    from repro.graphs import erdos_renyi
    adjs = [erdos_renyi(12, 0.4, seed=s) for s in range(2)]
    stream = GraphStream(adjs)
    laps = np.stack(stream.laplacians())
    engine = FGFTServeEngine(jnp.asarray(laps), 24, n_iter=1, dynamic=True,
                             policy=RefitPolicy(refresh=1e-9, extend=10.0,
                                                refit=10.0, num_probes=16,
                                                max_extends=0))
    return engine, stream


def _churn(engine, stream, rnd):
    from repro.graphs import weight_jitter
    for gid in range(len(stream.adjs)):
        batch = weight_jitter(stream.adjs[gid], 6, scale=0.2,
                              seed=100 * rnd + gid)
        engine.apply_updates(gid, stream.apply(gid, batch))


def test_engine_step_versioned_no_torn_reads(dyn_fleet):
    """Engine-level: concurrent step_versioned() during swaps must return
    (y, v) pairs where y is BITWISE the single-threaded answer of version
    v — a torn read mixing two versions' tables matches neither."""
    engine, stream = dyn_fleet
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 4, 12)).astype(np.float32))
    engine.warmup(x)                    # compile before the race starts
    expected = {}                       # version -> canonical output

    def snapshot():
        y, v = engine.step_versioned(x)
        expected[v] = np.asarray(y)

    snapshot()
    seen, errors = [], []
    stop = threading.Event()

    def hammer():
        try:
            while not stop.is_set():
                y, v = engine.step_versioned(x)
                seen.append((v, np.asarray(y)))
        except BaseException as exc:  # noqa: BLE001 — joined + re-raised below
            errors.append(exc)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    # only this thread mutates the engine, so right after each maintain()
    # the live version is stable and snapshot() records its exact answer
    for rnd in range(5):
        _churn(engine, stream, rnd)
        engine.maintain()
        snapshot()
    stop.set()
    for t in threads:
        t.join(30)
    assert not errors
    assert len(expected) >= 5           # the swaps actually happened
    assert len(seen) > 0
    for v, y in seen:
        assert v in expected, f"response carried unknown version {v}"
        assert np.array_equal(y, expected[v]), \
            f"torn read: output does not match version {v}"


def test_service_stress_versions_monotonic(dyn_fleet):
    """Service-level: tenant threads submit through the queue while the
    maintainer thread swaps versions.  Each tenant waits for its previous
    answer before the next submit, so the versions it observes must be
    non-decreasing; every payload must be finite."""
    engine, stream = dyn_fleet
    engine.warmup(jnp.asarray(np.zeros((2, 8, 12), np.float32)))
    baseline = _alive_non_daemon()
    rng = np.random.default_rng(1)
    svc = AsyncFGFTService(engine, max_queue=256, max_batch=4,
                           maintain_interval=None, name="stress")
    assert _alive_non_daemon() > baseline        # dispatcher + maintainer
    per_thread = {}
    errors = []

    def tenant(k):
        versions = per_thread[k] = []
        x = rng.standard_normal((2, 12)).astype(np.float32)
        try:
            for i in range(12):
                res = svc.submit((k + i) % 2, x).result(timeout=60)
                assert np.isfinite(res.y).all()
                versions.append(res.version)
        except BaseException as exc:  # noqa: BLE001 — joined + re-raised below
            errors.append(exc)

    tenants = [threading.Thread(target=tenant, args=(k,))
               for k in range(6)]
    for t in tenants:
        t.start()
    for rnd in range(4):                # churn + swap while they serve
        _churn(engine, stream, rnd)
        svc.maintain_now(timeout=60)
    for t in tenants:
        t.join(120)
    assert not errors
    stats = svc.stats()
    svc.close()
    assert stats["maintain"]["swaps"] >= 4
    assert stats["served"] == 6 * 12 and stats["errors"] == 0
    for k, versions in per_thread.items():
        assert len(versions) == 12
        assert versions == sorted(versions), \
            f"tenant {k} observed a version rollback: {versions}"
    # every maintainer/dispatcher thread is gone: count back to baseline
    assert _alive_non_daemon() == baseline


def test_maintain_failure_does_not_kill_serving(dyn_fleet, monkeypatch):
    """A refit that throws must surface through maintain_now() (with the
    original cause), count in stats, and leave both the maintainer thread
    and the serving path alive."""
    engine, stream = dyn_fleet
    real_maintain = engine.maintain
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise ValueError("probe matrix went singular")
        return real_maintain()

    monkeypatch.setattr(engine, "maintain", flaky)
    with AsyncFGFTService(engine, maintain_interval=None,
                          name="flaky") as svc:
        with pytest.raises(RuntimeError, match="maintenance tick failed") \
                as err:
            svc.maintain_now(timeout=60)
        assert isinstance(err.value.__cause__, ValueError)
        res = svc.maintain_now(timeout=60)       # next tick recovers
        assert res["action"] == "reuse"
        x = np.zeros((1, 12), np.float32)
        assert svc.submit(0, x).result(timeout=60).y.shape == (1, 12)
        st = svc.stats()["maintain"]
        assert st["errors"] == 1 and st["ticks"] == 1


def test_close_is_idempotent(dyn_fleet):
    engine, _ = dyn_fleet
    baseline = _alive_non_daemon()
    svc = AsyncFGFTService(engine, name="lifecycle")
    svc.close()
    svc.close()                          # second close: no-op, no raise
    assert _alive_non_daemon() == baseline


def test_shutdown_all_services_reaps_leaks(dyn_fleet):
    """The conftest thread-leak guard's escape hatch: a service a test
    forgot to close can be force-stopped fleet-wide."""
    engine, _ = dyn_fleet
    baseline = _alive_non_daemon()
    svc = AsyncFGFTService(engine, name="leaked")
    assert _alive_non_daemon() > baseline
    assert shutdown_all_services() == 1
    assert _alive_non_daemon() == baseline
    assert shutdown_all_services() == 0          # nothing left to reap
    with pytest.raises(Exception):               # noqa: B017 — closed is closed
        svc.submit(0, np.zeros((1, 12), np.float32))
