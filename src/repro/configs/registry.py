
"""--arch <id> lookup for every assigned architecture (+ smoke variants)."""
import importlib

_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "glm4-9b": "glm4_9b",
    "gemma2-27b": "gemma2_27b",
    "qwen2-7b": "qwen2_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "mamba2-780m": "mamba2_780m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_NAMES = list(_MODULES)

# Per-arch training memory recipe: whether FSDP (shard "embed" over "data")
# is required and the AdamW moment dtype.  Derived from per-chip HBM (v5e:
# 16 GB) at the production meshes; documented in EXPERIMENTS.md §Dry-run.
# kimi-k2 (1T params) additionally drops params+moments to bf16 — with f32
# everywhere, 12 TB of optimizer state cannot fit 512 x 16 GB at all.
# remat_block: k super-layers per activation-checkpoint block (nested
# remat) — trades ~+20% compute-term for ~-27% peak activation memory
# (measured, EXPERIMENTS.md §Perf).  Must divide the super-layer count
# (gemma2's 23 and kimi's 61 are prime -> 1).
RECIPES = {
    "qwen3-moe-30b-a3b": dict(fsdp=True, moment_dtype="float32",
                              remat_block=2),
    "kimi-k2-1t-a32b": dict(fsdp=True, moment_dtype="bfloat16",
                            param_dtype="bfloat16", remat_block=1),
    "glm4-9b": dict(fsdp=True, moment_dtype="float32", remat_block=2),
    "gemma2-27b": dict(fsdp=True, moment_dtype="float32", remat_block=1),
    "qwen2-7b": dict(fsdp=False, moment_dtype="float32", remat_block=4),
    "qwen2-1.5b": dict(fsdp=False, moment_dtype="float32", remat_block=4),
    "recurrentgemma-2b": dict(fsdp=False, moment_dtype="float32",
                              remat_block=2),
    "llama-3.2-vision-90b": dict(fsdp=True, moment_dtype="float32",
                                 remat_block=4),
    "mamba2-780m": dict(fsdp=False, moment_dtype="float32", remat_block=4),
    "seamless-m4t-large-v2": dict(fsdp=False, moment_dtype="float32",
                                  remat_block=4),
}


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.smoke() if smoke else mod.CONFIG
    if not smoke:
        r = RECIPES.get(name, {})
        pd = r.get("param_dtype")
        if pd is not None:
            import jax.numpy as jnp
            cfg = cfg.replace(param_dtype=getattr(jnp, pd))
        rb = r.get("remat_block", 1)
        if rb > 1:
            cfg = cfg.replace(remat_block=rb)
    return cfg


def get_recipe(name: str):
    """FSDP flag + moment dtype for the launcher / dry-run."""
    import jax.numpy as jnp
    r = dict(RECIPES.get(name, dict(fsdp=False, moment_dtype="float32")))
    r["moment_dtype"] = getattr(jnp, r["moment_dtype"])
    r.pop("param_dtype", None)
    r.pop("remat_block", None)  # applied through get_config
    return r
