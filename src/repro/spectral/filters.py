"""Spectral filter bank over fast approximate eigenbases (DESIGN.md §8).

A *response* is a scalar gain function of the graph frequencies:
``h(lam) -> gains`` with ``lam`` the estimated spectrum, (n,) or (B, n).
Responses here self-normalize against the per-graph spectral range
(``lam.max`` along the last axis), so one response serves a whole batch of
graphs with different Laplacian scales — the form the batched engine wants
(core/eigenbasis.py).

The factories cover the classic GSP toolbox: heat-kernel smoothing,
Butterworth low/high-pass, Gaussian band-pass, Tikhonov denoising
(``argmin_y ||y - x||^2 + tau y^T L y`` has the closed form
``y = (I + tau L)^{-1} x``, i.e. the gain ``1/(1 + tau lam)``), and
Hammond-style spectral-graph-wavelet scales (arXiv:0912.3848: a band-pass
kernel ``g(x) = x e^{1-x}`` evaluated at log-spaced scales plus a low-pass
scaling function).

``SpectralFilter``/``SpectralFilterBank`` bind responses to a fitted
``ApproxEigenbasis``; ``SpectralFilterBank.apply`` routes a whole bank
through one fused dispatch (kernels/spectral.py via an ApplyPlan) so the
analysis transform is paid once for all F filters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

Response = Callable[[jnp.ndarray], jnp.ndarray]


def _lmax(lam: jnp.ndarray) -> jnp.ndarray:
    """Per-graph spectral range, guarded against degenerate spectra."""
    return jnp.maximum(jnp.max(jnp.abs(lam), axis=-1, keepdims=True), 1e-12)


def heat(scale: float = 5.0) -> Response:
    """Heat-kernel smoothing  exp(-scale · lam / lam_max)  (diffusion for
    ``scale`` units of normalized time; larger = smoother)."""
    return lambda lam: jnp.exp(-scale * lam / _lmax(lam))


def tikhonov(tau: float = 1.0) -> Response:
    """Tikhonov denoiser  1 / (1 + tau · lam / lam_max)  — the closed-form
    minimizer of ||y - x||^2 + tau~ y^T L y with tau~ = tau/lam_max."""
    return lambda lam: 1.0 / (1.0 + tau * lam / _lmax(lam))


def lowpass(frac: float = 0.25, order: int = 4) -> Response:
    """Butterworth low-pass with cutoff at ``frac`` of the spectral range."""
    return lambda lam: 1.0 / (1.0 + (lam / (frac * _lmax(lam)))
                              ** (2 * order))


def highpass(frac: float = 0.25, order: int = 4) -> Response:
    """Complement of ``lowpass``: passes frequencies above the cutoff."""
    lp = lowpass(frac, order)
    return lambda lam: 1.0 - lp(lam)


def bandpass(center_frac: float = 0.5, width_frac: float = 0.15
             ) -> Response:
    """Gaussian band-pass centered at ``center_frac`` of the range."""

    def resp(lam):
        mx = _lmax(lam)
        z = (lam - center_frac * mx) / (width_frac * mx)
        return jnp.exp(-z * z)

    return resp


def hammond_kernel(x: jnp.ndarray) -> jnp.ndarray:
    """SGWT band-pass kernel  g(x) = x · e^{1-x}  (peak g(1) = 1)."""
    return x * jnp.exp(1.0 - x)


def wavelet_scales(num_scales: int = 4, scale_ratio: float = 20.0
                   ) -> np.ndarray:
    """Log-spaced SGWT scales t_j (coarse -> fine) in normalized frequency
    units: t_j · lam/lam_max sweeps the kernel's pass band across
    [lam_max/scale_ratio, lam_max] (Hammond et al. §8 design rule)."""
    return np.logspace(np.log10(scale_ratio), 0.0, num_scales)


def hammond_bank(num_scales: int = 4, scale_ratio: float = 20.0
                 ) -> "Dict[str, Response]":
    """Scaling function + ``num_scales`` wavelet responses.

    The scaling function covers the lam -> 0 end (where every wavelet
    vanishes, g(0) = 0); together the bank tiles the whole spectrum."""
    scales = wavelet_scales(num_scales, scale_ratio)
    t_coarse = float(scales[0])

    def scaling(lam):
        return jnp.exp(-(t_coarse * lam / _lmax(lam)) ** 4)

    bank: Dict[str, Response] = {"scaling": scaling}
    for j, t in enumerate(scales):
        t = float(t)
        bank[f"wavelet{j}"] = (
            lambda lam, t=t: hammond_kernel(t * lam / _lmax(lam)))
    return bank


def response_lipschitz(response: Response, lmax: float = 1.0,
                       num: int = 512) -> float:
    """Dimensionless Lipschitz constant of a response on [0, lmax]:
    ``max |dh/dlam| · lmax``, estimated on a dense grid.

    Converts a basis approximation error into the filtering error it
    implies — ``||h(Sbar) - h(S)|| <~ Lip(h) ||Sbar - S||`` — which is the
    per-filter accuracy bound asserted by benchmarks/fig8_spectral.py and
    tests/test_spectral.py (narrow responses amplify spectral error)."""
    lam = jnp.linspace(0.0, lmax, num)
    h = response(lam)
    d = jnp.abs(jnp.diff(h) / jnp.diff(lam))
    return float(jnp.max(d) * lmax)


RESPONSES: Dict[str, Callable[..., Response]] = {
    "heat": heat,
    "tikhonov": tikhonov,
    "lowpass": lowpass,
    "highpass": highpass,
    "bandpass": bandpass,
}


def named_responses(spec: str) -> "Dict[str, Response]":
    """Parse a serve-style bank spec: comma-separated names with an
    optional ``:param`` (e.g. ``"heat:3.0,lowpass,wavelets:4"``).

    ``wavelets[:J]`` expands to the Hammond scaling function + J wavelet
    scales; every other name maps through ``RESPONSES`` with the optional
    float as its first parameter."""
    bank: Dict[str, Response] = {}

    def add(key: str, resp: Response):
        if key in bank:
            raise ValueError(f"duplicate filter {key!r} in bank spec "
                             f"{spec!r} — each response would silently "
                             "overwrite the previous one")
        bank[key] = resp

    for item in filter(None, (s.strip() for s in spec.split(","))):
        name, _, param = item.partition(":")
        if name == "wavelets":
            for key, resp in hammond_bank(int(param) if param else 4
                                          ).items():
                add(key, resp)
            continue
        if name not in RESPONSES:
            raise ValueError(f"unknown filter {name!r}; known: "
                             f"{sorted(RESPONSES)} + 'wavelets'")
        add(item, (RESPONSES[name](float(param)) if param
                   else RESPONSES[name]()))
    return bank


def _mask_padded_gains(gains: jnp.ndarray, basis) -> jnp.ndarray:
    """Zero the gains at a ragged basis's padding coordinates.

    A masked (size-bucketed) fit carries zeros in the padded spectrum
    slots, but a response may map 0 to a nonzero gain (heat/tikhonov:
    h(0) = 1).  ``ApproxEigenbasis.project`` masks its own gains at
    depth; this helper covers the FUSED bank path (``SpectralFilterBank
    .apply`` dispatches precomputed (B, F, n) gains straight into the
    bank kernels, bypassing ``project``) and the public ``gains()``
    contract (DESIGN.md §10)."""
    sizes = getattr(basis, "sizes", None)
    if sizes is None:
        return gains
    n = gains.shape[-1]
    # batched: (B,) sizes -> (B, n) mask; unbatched: scalar size -> (n,)
    # mask (a reshape(-1, 1) here would silently grow (n,) gains to
    # (1, n) and break the gains() shape contract)
    valid = np.arange(n) < np.asarray(sizes)[..., None]
    return jnp.where(jnp.asarray(valid), gains, 0.0)


@dataclass(frozen=True)
class SpectralFilter:
    """One response bound to a fitted basis: y = Ubar diag(h(s)) Ubar^T x.

    ``basis`` may be single ((n, n) fit) or batched ((B, n, n) fit); the
    signal layout follows ``ApproxEigenbasis.project``.  For a ragged
    (size-bucketed) basis the gains are zeroed at each graph's padding
    coordinates, so padded signal columns filter to zero."""

    basis: object               # ApproxEigenbasis
    response: Response
    name: str = "filter"

    def gains(self) -> jnp.ndarray:
        """Diagonal gains h(spectrum): (n,) or (B, n)."""
        return _mask_padded_gains(self.response(self.basis.spectrum),
                                  self.basis)

    def apply(self, x: jnp.ndarray, backend: str = "xla") -> jnp.ndarray:
        """Filter signals x (..., n) / (B, ..., n) -> same shape
        (``project`` itself zeroes the gains at a ragged basis's padding
        coordinates; the explicit mask here is only for the fused bank
        path, which bypasses ``project``)."""
        return self.basis.project(x, h=self.response, backend=backend)


class SpectralFilterBank:
    """F responses served through one fused dispatch per signal block.

    ``responses``: dict name -> response (order preserved) or a sequence
    of (name, response) pairs.  ``apply`` returns the filter axis FIRST
    after any matrix batch: (F, ..., n) unbatched, (B, F, ..., n) batched.
    """

    def __init__(self, basis, responses):
        if isinstance(responses, dict):
            items: Sequence[Tuple[str, Response]] = list(responses.items())
        else:
            items = list(responses)
        if not items:
            raise ValueError("empty filter bank")
        self.basis = basis
        self.names = [name for name, _ in items]
        self.filters = [SpectralFilter(basis, resp, name)
                        for name, resp in items]

    def __len__(self) -> int:
        return len(self.filters)

    def gains(self) -> jnp.ndarray:
        """Stacked diagonal gains: (F, n) or (B, F, n) when batched."""
        axis = 1 if self.basis.batched else 0
        return jnp.stack([f.gains() for f in self.filters], axis=axis)

    def apply(self, x: jnp.ndarray, backend: str = "xla",
              fused: bool = True) -> jnp.ndarray:
        """Filter x through every response.

        ``fused=True`` dispatches the whole bank at once (one analysis
        pass shared by all F filters; ``backend="pallas"`` additionally
        runs the one-launch kernel).  ``fused=False`` is the per-filter
        composition — kept as the semantics baseline and the thing
        benchmarks/fig8_spectral.py races against."""
        from repro.kernels.plan import ApplyPlan
        basis = self.basis
        if not fused:
            axis = 1 if basis.batched else 0
            return jnp.stack([f.apply(x, backend=backend)
                              for f in self.filters], axis=axis)
        plan = ApplyPlan.for_staged(basis.fwd, mode="bank",
                                    backend=backend)
        return plan.bank(basis.fwd, basis.bwd, self.gains(), x)
