"""Dynamic-graph subsystem (DESIGN.md §11): streaming update tracking,
Hutchinson drift scoring, the refit-policy state machine, and versioned
hot-swap serving with checkpoint round-trips."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ApproxEigenbasis, laplacian
from repro.dynamic import (Action, GraphStream, RefitController,
                           RefitPolicy, UpdateBatch, apply_update,
                           delta_adjacency, drift_score,
                           estimate_rel_residual, exact_rel_residual,
                           laplacian_delta, lemma1_refresh,
                           make_update_batch, merge_batches)
from repro.graphs import (community_graph, edge_perturbation, erdos_renyi,
                          weight_jitter)


def _sym_laps(b, n, seed=0):
    return np.stack([laplacian(erdos_renyi(n, 0.3, seed=seed + s))
                     for s in range(b)])


def _perturbed(laps, rows, num_edges, seed=7):
    """Copy of ``laps`` with a topology perturbation applied to the
    given rows (via the adjacency so the result stays a Laplacian)."""
    out = laps.copy()
    for r in rows:
        adj = np.diag(np.diag(laps[r])) - laps[r]
        np.fill_diagonal(adj, 0.0)
        batch = edge_perturbation(adj, num_edges, seed=seed + r)
        out[r] = laplacian(apply_update(adj, batch))
    return out


# ---------------------------------------------------------------------------
# stream.py: update batches compose exactly
# ---------------------------------------------------------------------------


def test_update_batch_validation():
    with pytest.raises(ValueError, match="off-diagonal"):
        make_update_batch([0], [0], [1.0])
    with pytest.raises(ValueError, match="one length"):
        make_update_batch([0, 1], [2], [1.0])
    with pytest.raises(ValueError, match=">= n"):
        laplacian_delta(make_update_batch([0], [9], [1.0]), 4)
    b = make_update_batch([0, 1], [2, 3], [1.0, -0.5])
    assert b.num_edges == 2 and b.symmetric


def test_laplacian_delta_composes():
    adj = community_graph(12, seed=0)
    batch = edge_perturbation(adj, 5, seed=1)
    np.testing.assert_allclose(
        laplacian(adj) + laplacian_delta(batch, 12),
        laplacian(apply_update(adj, batch)), atol=1e-6)
    dw = delta_adjacency(batch, 12)
    np.testing.assert_allclose(dw, dw.T, atol=0)   # mirrored


def test_graph_stream_tracks_and_rejects_mismatched_symmetry():
    adjs = [community_graph(10, seed=0), community_graph(14, seed=1)]
    stream = GraphStream(adjs)
    assert stream.sizes == [10, 14]
    batch = edge_perturbation(adjs[1], 3, seed=2)
    lap_before = stream.laplacian(1)
    dl = stream.apply(1, batch)
    np.testing.assert_allclose(lap_before + dl, stream.laplacian(1),
                               atol=1e-6)
    assert stream.updates_applied.tolist() == [0, 1]
    with pytest.raises(ValueError, match="directed"):
        stream.apply(0, UpdateBatch(np.array([0]), np.array([1]),
                                    np.array([1.0], np.float32),
                                    symmetric=False))


def test_merge_batches():
    a = make_update_batch([0], [1], [1.0])
    b = make_update_batch([2], [3], [-1.0])
    m = merge_batches([a, b])
    assert m.num_edges == 2
    assert merge_batches([]) is None
    with pytest.raises(ValueError, match="merge"):
        merge_batches([a, make_update_batch([0], [1], [1.0],
                                            symmetric=False)])


# ---------------------------------------------------------------------------
# drift.py: Hutchinson estimate vs dense residual, monotonicity
# ---------------------------------------------------------------------------


def test_drift_estimate_matches_exact_sym_batched():
    laps = _sym_laps(3, 16)
    basis = ApproxEigenbasis.fit(jnp.asarray(laps), 32, n_iter=1)
    exact = exact_rel_residual(basis, laps)
    est = estimate_rel_residual(basis, laps, num_probes=256, seed=0)
    np.testing.assert_allclose(est, exact, rtol=0.3)


@pytest.mark.slow
def test_drift_estimate_matches_exact_general():
    mats = np.random.default_rng(0).standard_normal((2, 12, 12)).astype(
        np.float32)
    basis = ApproxEigenbasis.fit(jnp.asarray(mats), 24, n_iter=1,
                                 kind="general")
    exact = exact_rel_residual(basis, mats)
    est = estimate_rel_residual(basis, mats, num_probes=256, seed=1)
    np.testing.assert_allclose(est, exact, rtol=0.3)


def test_drift_estimate_matches_exact_ragged_masked(ragged_sym_fit):
    from repro.core import pad_ragged
    fleet, basis = ragged_sym_fit
    stack, _ = pad_ragged(fleet)
    exact = exact_rel_residual(basis, stack)
    est = estimate_rel_residual(basis, stack, num_probes=256, seed=2)
    np.testing.assert_allclose(est, exact, rtol=0.3, atol=1e-4)


def test_drift_score_zero_on_own_laps_and_monotone():
    laps = _sym_laps(3, 16)
    basis = ApproxEigenbasis.fit(jnp.asarray(laps), 48, n_iter=1)
    base = drift_score(basis, laps, num_probes=128)
    assert np.all(base < 0.01)        # ~0 up to estimator noise
    prev = base[1]
    for num_edges in (4, 12, 30):     # growing perturbation
        pert = _perturbed(laps, [1], num_edges)
        d = drift_score(basis, pert, num_probes=128)
        assert d[1] > prev - 1e-6
        assert d[1] > base[1]
        assert d[0] == pytest.approx(base[0], abs=1e-6)  # untouched rows
        prev = d[1]


def test_lemma1_refresh_matches_direct_conjugation():
    laps = _sym_laps(2, 12)
    basis = ApproxEigenbasis.fit(jnp.asarray(laps), 24, n_iter=1)
    pert = _perturbed(laps, [0, 1], 6)
    refreshed = np.asarray(lemma1_refresh(basis, jnp.asarray(pert)))
    u = np.asarray(basis.to_dense())
    want = np.stack([np.diag(u[b].T @ pert[b] @ u[b]) for b in range(2)])
    np.testing.assert_allclose(refreshed, want, rtol=1e-4, atol=1e-4)
    # the refresh is the Lemma-1 optimum for the FIXED chain: it never
    # increases the residual on the new Laplacians
    from dataclasses import replace
    refit = replace(basis, spectrum=jnp.asarray(refreshed), objective=None)
    assert np.all(exact_rel_residual(refit, pert)
                  <= exact_rel_residual(basis, pert) + 1e-6)


# ---------------------------------------------------------------------------
# refit.py: policy thresholds, hysteresis escalation, budgets
# ---------------------------------------------------------------------------


def test_policy_validation():
    with pytest.raises(ValueError, match="ascending"):
        RefitPolicy(refresh=0.5, extend=0.1)
    with pytest.raises(ValueError, match="hysteresis"):
        RefitPolicy(hysteresis=0.0)
    with pytest.raises(ValueError, match="extend_fraction"):
        RefitPolicy(extend_fraction=0.0)


def test_controller_threshold_mapping():
    c = RefitController(RefitPolicy(refresh=0.01, extend=0.1, refit=0.5))
    assert c.decide([0.001]) is Action.REUSE
    assert c.decide([0.05]) is Action.REFRESH
    assert c.decide([0.2]) is Action.EXTEND
    assert c.decide([0.9]) is Action.REFIT
    assert c.decide([]) is Action.REUSE
    # a family without a cheap spectrum refresh (general/T) escalates a
    # refresh-level trigger to EXTEND, still under the extend budget
    assert c.decide([0.05], can_refresh=False) is Action.EXTEND
    c0 = RefitController(RefitPolicy(refresh=0.01, extend=0.1, refit=0.5,
                                     max_extends=0))
    assert c0.decide([0.05], can_refresh=False) is Action.REFIT


def test_controller_hysteresis_escalates_ineffective_actions():
    c = RefitController(RefitPolicy(refresh=0.01, extend=0.1, refit=0.5,
                                    hysteresis=0.5))
    # refresh leaves drift above the re-arm point -> next same-level
    # trigger escalates instead of flapping
    c.record(Action.REFRESH, [0.02])
    assert c.decide([0.05]) is Action.EXTEND
    # a successful action (drift below hysteresis x threshold) re-arms
    c.record(Action.EXTEND, [0.001])
    assert c.decide([0.05]) is Action.REFRESH
    # escalation saturates at REFIT
    c.record(Action.REFIT, [0.9])
    assert c.decide([0.9]) is Action.REFIT


def test_controller_max_extends_forces_refit_and_state_roundtrip():
    c = RefitController(RefitPolicy(refresh=0.01, extend=0.1, refit=0.5,
                                    max_extends=2))
    for _ in range(2):
        assert c.decide([0.2]) is Action.EXTEND
        c.record(Action.EXTEND, [0.001])
    assert c.decide([0.2]) is Action.REFIT
    c.record(Action.REFIT, [0.001])
    assert c.extends_since_refit == 0
    assert c.decide([0.2]) is Action.EXTEND
    c2 = RefitController(c.policy)
    c2.load_state_dict(c.state_dict())
    assert c2.counts == c.counts
    assert c2.extends_since_refit == c.extends_since_refit


# ---------------------------------------------------------------------------
# Versioned hot-swap serving (launch/serve.py dynamic mode)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dynamic_engine():
    """(stream, engine): a B=3 dynamic engine with a refresh-friendly
    policy, shared across the serving tests (module-scoped: each test
    perturbs different graphs/rounds)."""
    from repro.launch.serve import FGFTServeEngine
    adjs = [community_graph(16, seed=s) for s in range(3)]
    stream = GraphStream(adjs)
    laps = np.stack(stream.laplacians())
    policy = RefitPolicy(refresh=0.004, extend=0.3, refit=0.6,
                         num_probes=64, hysteresis=1.0)
    engine = FGFTServeEngine(jnp.asarray(laps), 48, n_iter=1,
                             tiers={"full": 1.0, "draft": 0.25},
                             dynamic=True, policy=policy)
    return stream, engine


def test_dynamic_reuse_below_threshold(dynamic_engine):
    stream, engine = dynamic_engine
    res = engine.maintain()                      # nothing dirty
    assert res["action"] == "reuse"
    # a tiny reweight stays under the refresh threshold
    batch = weight_jitter(stream.adjs[2], 2, scale=0.01, seed=3)
    engine.apply_updates(2, stream.apply(2, batch))
    v0 = engine.versions.copy()
    res = engine.maintain()
    assert res["action"] == "reuse"
    np.testing.assert_array_equal(engine.versions, v0)


def test_dynamic_refresh_swaps_without_recompiling(dynamic_engine):
    stream, engine = dynamic_engine
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (3, 4, 16)).astype(np.float32))
    h = lambda lam: 1.0 / (1.0 + lam)  # noqa: E731
    engine.warmup(x)
    assert all(v == 0 for v in engine.stats["steps"].values())
    y0 = np.asarray(engine.step(x, h))
    progs = {name: engine._live.fns[name] for name in engine.tiers}
    sizes0 = {name: p._cache_size() for name, p in progs.items()}
    versions0 = engine.versions.copy()

    batch = edge_perturbation(stream.adjs[1], 3, seed=11)
    engine.apply_updates(1, stream.apply(1, batch))
    res = engine.maintain()
    assert res["action"] == "refresh"
    assert engine.versions[1] == versions0[1] + 1
    assert engine.versions[0] == versions0[0]    # untouched graph
    y1 = np.asarray(engine.step(x, h))
    assert np.abs(y1 - y0).max() > 0             # updated basis serves
    for name, p in progs.items():
        assert p._cache_size() == sizes0[name]   # zero recompiles
    # the served spectrum IS the Lemma-1 refresh on the updated laps
    want = np.asarray(lemma1_refresh(engine.basis,
                                     jnp.asarray(engine._laps_host)))
    np.testing.assert_allclose(np.asarray(engine.basis.spectrum), want,
                               rtol=1e-5, atol=1e-5)
    dyn = engine.stats["dynamic"]
    assert dyn["actions"]["refresh"] >= 1
    assert dyn["versions"] == engine.versions.tolist()


@pytest.mark.slow
def test_dynamic_extend_and_refit_paths():
    from repro.launch.serve import FGFTServeEngine
    adjs = [community_graph(16, seed=s) for s in range(2)]
    stream = GraphStream(adjs)
    laps = np.stack(stream.laplacians())
    policy = RefitPolicy(refresh=0.0005, extend=0.002, refit=0.5,
                         extend_fraction=0.25, max_extends=1,
                         num_probes=64, hysteresis=1.0)
    engine = FGFTServeEngine(jnp.asarray(laps), 32, n_iter=1,
                             tiers={"full": 1.0}, dynamic=True,
                             policy=policy)
    g0 = engine.basis.num_transforms
    batch = edge_perturbation(stream.adjs[0], 8, seed=5)
    engine.apply_updates(0, stream.apply(0, batch))
    res = engine.maintain()
    assert res["action"] == "extend"
    assert engine.basis.num_transforms == g0 + 8     # 0.25 * 32
    assert np.all(engine.versions >= 1)              # whole batch regrown
    # second structural trigger exceeds max_extends -> full refit at g0
    batch = edge_perturbation(stream.adjs[1], 8, seed=6)
    engine.apply_updates(1, stream.apply(1, batch))
    res = engine.maintain()
    assert res["action"] == "refit"
    assert engine.basis.num_transforms == g0
    assert engine.controller.extends_since_refit == 0


def test_dynamic_engine_validation(dynamic_engine):
    from repro.launch.serve import FGFTServeEngine
    stream, engine = dynamic_engine
    with pytest.raises(ValueError, match="exceeds"):
        engine.apply_updates(0, np.zeros((32, 32), np.float32))
    static = FGFTServeEngine(
        jnp.asarray(np.stack(GraphStream(
            [community_graph(8, seed=0)]).laplacians())), 12, n_iter=0)
    with pytest.raises(ValueError, match="dynamic"):
        static.apply_updates(0, np.zeros((8, 8), np.float32))
    with pytest.raises(ValueError, match="dynamic"):
        static.maintain()


# ---------------------------------------------------------------------------
# Ragged router: per-bucket swaps, request-order versions
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ragged_dynamic_routing_and_versions():
    from repro.launch.serve import RaggedFGFTServeEngine
    sizes = [10, 16, 24]
    adjs = [community_graph(n, seed=i) for i, n in enumerate(sizes)]
    stream = GraphStream(adjs)
    policy = RefitPolicy(refresh=0.003, extend=0.4, refit=0.8,
                         num_probes=64, hysteresis=1.0)
    router = RaggedFGFTServeEngine(stream.laplacians(), 48, n_iter=1,
                                   tiers={"full": 1.0}, dynamic=True,
                                   policy=policy)
    rng = np.random.default_rng(0)
    signals = [rng.standard_normal((2, n)).astype(np.float32)
               for n in sizes]
    h = lambda lam: 1.0 / (1.0 + lam)  # noqa: E731
    y0 = router.step(signals, h)
    batch = edge_perturbation(stream.adjs[2], 4, seed=4)
    router.apply_updates(2, stream.apply(2, batch))
    res = router.maintain()
    # only graph 2's bucket acts; the other bucket reuses
    acted = {w: r["action"] for w, r in res.items()}
    assert acted[router.widths[2]] != "reuse"
    assert acted[router.widths[0]] == "reuse"
    assert router.versions.tolist()[:2] == [0, 0]
    assert router.versions[2] >= 1
    assert router.drift().shape == (3,)
    y1 = router.step(signals, h)
    assert [a.shape for a in y1] == [b.shape for b in y0]


# ---------------------------------------------------------------------------
# Checkpoint: versions + counters round-trip; pre-versioned defaults
# ---------------------------------------------------------------------------


def test_dynamic_engine_checkpoint_roundtrip(tmp_path):
    from repro.launch.serve import FGFTServeEngine
    adjs = [community_graph(12, seed=s) for s in range(2)]
    stream = GraphStream(adjs)
    policy = RefitPolicy(refresh=0.002, extend=0.4, refit=0.8,
                         num_probes=64, hysteresis=1.0)
    engine = FGFTServeEngine(jnp.asarray(np.stack(stream.laplacians())),
                             24, n_iter=1, tiers={"full": 1.0},
                             dynamic=True, policy=policy)
    batch = edge_perturbation(stream.adjs[0], 4, seed=9)
    engine.apply_updates(0, stream.apply(0, batch))
    engine.maintain()
    engine.save(tmp_path, step=5)
    restored = FGFTServeEngine.load(tmp_path)
    assert restored.dynamic
    np.testing.assert_array_equal(restored.versions, engine.versions)
    np.testing.assert_allclose(np.asarray(restored._laps_host),
                               np.asarray(engine._laps_host), atol=1e-6)
    assert restored.controller.counts == engine.controller.counts
    assert restored._live.version == engine._live.version
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, 3, 12)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(restored.step(x)),
                               np.asarray(engine.step(x)),
                               rtol=1e-5, atol=1e-5)


def test_pre_versioned_checkpoint_defaults_to_version_zero(tmp_path):
    """A checkpoint written by plain ApproxEigenbasis.save (no dynamic
    metadata, no version key — the pre-§11 format) must load with every
    version at 0 and fresh counters, never a KeyError."""
    import json
    from repro.launch.serve import FGFTServeEngine
    laps = _sym_laps(2, 12)
    basis = ApproxEigenbasis.fit(jnp.asarray(laps), 24, n_iter=1)
    basis.save(tmp_path, step=1)
    # strip the version key to simulate the PRE-versioned manifest
    manifest_path = tmp_path / "step_000000001" / "manifest.json"
    manifest = json.loads(manifest_path.read_text())
    manifest["metadata"]["eigenbasis"].pop("version")
    manifest["metadata"]["eigenbasis"].pop("stage_pad")
    manifest_path.write_text(json.dumps(manifest))

    loaded = ApproxEigenbasis.load(tmp_path)
    assert loaded.info["version"] == 0
    engine = FGFTServeEngine.load(tmp_path, laps=jnp.asarray(laps),
                                  dynamic=True, tiers={"full": 1.0})
    assert engine.versions.tolist() == [0, 0]
    assert engine.controller.counts == {a.value: 0 for a in Action}
    assert engine._live.version == 0


@pytest.mark.slow
def test_ragged_router_checkpoint_roundtrip(tmp_path):
    from repro.launch.serve import RaggedFGFTServeEngine
    sizes = [10, 16]
    adjs = [community_graph(n, seed=i) for i, n in enumerate(sizes)]
    stream = GraphStream(adjs)
    router = RaggedFGFTServeEngine(
        stream.laplacians(), 32, n_iter=0, tiers={"full": 1.0},
        dynamic=True,
        policy=RefitPolicy(refresh=0.002, num_probes=64, hysteresis=1.0))
    batch = edge_perturbation(stream.adjs[1], 3, seed=2)
    router.apply_updates(1, stream.apply(1, batch))
    router.maintain()
    router.save(tmp_path, step=2)
    restored = RaggedFGFTServeEngine.load(tmp_path)
    assert restored.sizes == router.sizes
    np.testing.assert_array_equal(restored.versions, router.versions)
    rng = np.random.default_rng(3)
    signals = [rng.standard_normal((2, n)).astype(np.float32)
               for n in sizes]
    a = router.step(signals)
    b = restored.step(signals)
    for ya, yb in zip(a, b):
        np.testing.assert_allclose(ya, yb, rtol=1e-5, atol=1e-5)
